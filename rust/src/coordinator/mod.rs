//! L3 coordinator: continuous-batching serving on top of an [`Engine`].
//!
//! [`Scheduler`] is the synchronous core (resume swapped → token-budget
//! plan/admit → one fused decode+prefill-chunk step → retire);
//! [`Coordinator`] wraps it in a background thread with a channel-based
//! submit/receive API for the TCP server and examples.
//!
//! Admission and preemption are KV-block-lifecycle aware: prompts sharing
//! a cached prefix skip that part of prefill ([`Engine::prefill_shared`]),
//! and capacity preemption swaps sequences out to the cache's spill buffer
//! instead of discarding them ([`Engine::swap_out`]) — see DESIGN.md
//! §KV-lifecycle. The scheduler mirrors cache occupancy into
//! [`crate::metrics::Metrics`] every step, so `{"op":"metrics"}` reports
//! prefix-hit rate and swap counts live.

pub mod cpu_engine;
pub mod engine;
pub mod scheduler;

pub use cpu_engine::CpuEngine;
pub use engine::{ChunkInput, DecodeInput, Engine, EngineError, StepOutput, VerifyInput};
pub use scheduler::{FinishReason, Request, Response, Scheduler, SchedulerCfg};

use crate::metrics::Metrics;
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

enum Msg {
    Submit(Request, Sender<Response>),
    Cancel(u64, Sender<bool>),
    Shutdown,
}

/// Thread-hosted scheduler with a channel API.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Spawn the engine loop on a background thread (engines that are
    /// `Send`, e.g. [`CpuEngine`]).
    pub fn spawn<E: Engine + Send + 'static>(engine: E, cfg: SchedulerCfg) -> Self {
        Self::spawn_with(move || engine, cfg)
    }

    /// Spawn with an engine *factory* executed on the coordinator thread —
    /// required for [`crate::runtime::PjrtEngine`], whose PJRT handles are
    /// `Rc`-based and must never cross threads.
    pub fn spawn_with<E, F>(factory: F, cfg: SchedulerCfg) -> Self
    where
        E: Engine + 'static,
        F: FnOnce() -> E + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::Builder::new()
            .name("skipless-coordinator".into())
            .spawn(move || engine_loop(factory(), cfg, rx, m2))
            .expect("spawn coordinator");
        Self {
            tx,
            handle: Some(handle),
            metrics,
        }
    }

    /// Spawn a self-speculating scheduler: `draft` (typically the INT8
    /// copy of the target weights) proposes [`SchedulerCfg::spec_k`] tokens
    /// per sequence per step, `engine` verifies them in one widened batched
    /// step — token-identical greedy output (see [`Scheduler::with_draft`]).
    pub fn spawn_speculative<E, D>(engine: E, draft: D, cfg: SchedulerCfg) -> Self
    where
        E: Engine + Send + 'static,
        D: Engine + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::Builder::new()
            .name("skipless-coordinator".into())
            .spawn(move || sched_loop(Scheduler::with_draft(engine, Box::new(draft), cfg, m2), rx))
            .expect("spawn coordinator");
        Self {
            tx,
            handle: Some(handle),
            metrics,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Submit(req, tx)).expect("coordinator alive");
        rx
    }

    /// Submit and block for the response. A request whose reply channel is
    /// lost (coordinator shutdown mid-request) comes back Rejected rather
    /// than panicking the caller's thread.
    pub fn generate(&self, req: Request) -> Response {
        let id = req.id;
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| Response::empty(id, FinishReason::Rejected))
    }

    /// Cancel an in-flight request by id ([`Scheduler::cancel`]): resources
    /// release immediately and the submitter receives a
    /// [`crate::coordinator::FinishReason::Cancelled`] response. Returns
    /// false when the request already finished (or was never submitted).
    pub fn cancel(&self, id: u64) -> bool {
        let (tx, rx) = channel();
        if self.tx.send(Msg::Cancel(id, tx)).is_err() {
            return false;
        }
        rx.recv().unwrap_or(false)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn engine_loop<E: Engine>(
    engine: E,
    cfg: SchedulerCfg,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) {
    sched_loop(Scheduler::new(engine, cfg, metrics), rx)
}

fn sched_loop<E: Engine>(mut sched: Scheduler<E>, rx: Receiver<Msg>) {
    let mut reply_to: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
    loop {
        // Drain pending messages; block only when fully idle.
        loop {
            // deliver anything already finished BEFORE potentially
            // blocking — a cancel can retire the last in-flight request
            // without a step ever running again
            for resp in sched.take_done() {
                if let Some(tx) = reply_to.remove(&resp.id) {
                    let _ = tx.send(resp);
                }
            }
            let msg = if sched.is_idle() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return, // all senders gone
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => return,
                }
            };
            match msg {
                Msg::Submit(req, tx) => {
                    // first wins: a duplicate in-flight id is rejected
                    // outright rather than hijacking the earlier
                    // submitter's reply channel
                    if reply_to.contains_key(&req.id) {
                        let _ = tx.send(Response::empty(req.id, FinishReason::Rejected));
                    } else {
                        reply_to.insert(req.id, tx);
                        sched.submit(req);
                    }
                }
                Msg::Cancel(id, tx) => {
                    // the Cancelled response reaches the submitter through
                    // the normal take_done → reply_to delivery below
                    let _ = tx.send(sched.cancel(id));
                }
                Msg::Shutdown => return,
            }
        }
        sched.step();
        for resp in sched.take_done() {
            if let Some(tx) = reply_to.remove(&resp.id) {
                let _ = tx.send(resp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::{greedy_generate, ModelWeights};

    fn coordinator(seed: u64) -> (Coordinator, ModelWeights) {
        let cfg = ModelConfig::tiny_gqa();
        let w = ModelWeights::init_vanilla(&cfg, seed);
        let c = Coordinator::spawn(
            CpuEngine::new(w.clone(), 8, 16 << 20),
            SchedulerCfg::default(),
        );
        (c, w)
    }

    #[test]
    fn generate_blocking_roundtrip() {
        let (c, w) = coordinator(70);
        let want = greedy_generate(&w, &[1, 2, 3], 5);
        let resp = c.generate(Request::greedy(1, vec![1, 2, 3], 5));
        assert_eq!(resp.tokens, want);
        c.shutdown();
    }

    #[test]
    fn concurrent_submitters() {
        let (c, w) = coordinator(71);
        let c = Arc::new(c);
        let handles: Vec<_> = (0..8u64)
            .map(|i| {
                let c = Arc::clone(&c);
                let w = w.clone();
                std::thread::spawn(move || {
                    let prompt = vec![(i % 5 + 1) as u32, 2, 3];
                    let want = greedy_generate(&w, &prompt, 4);
                    let resp = c.generate(Request::greedy(i, prompt, 4));
                    assert_eq!(resp.tokens, want, "request {i}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn metrics_visible_from_outside() {
        let (c, _) = coordinator(72);
        let _ = c.generate(Request::greedy(1, vec![4, 4], 3));
        use std::sync::atomic::Ordering;
        assert_eq!(c.metrics().requests_completed.load(Ordering::Relaxed), 1);
        c.shutdown();
    }

    #[test]
    fn cancel_reaches_the_scheduler() {
        use crate::coordinator::scheduler::FinishReason;
        let (c, _) = coordinator(75);
        // a long request we try to cancel mid-flight; the race with natural
        // completion is inherent, so accept either outcome consistently
        let rx = c.submit(Request::greedy(42, vec![1, 2, 3], 64));
        let cancelled = c.cancel(42);
        let resp = rx.recv().expect("response still delivered");
        if cancelled {
            assert_eq!(resp.finish, FinishReason::Cancelled);
            assert!(resp.tokens.len() < 64);
        } else {
            assert_eq!(resp.finish, FinishReason::Length);
        }
        // cancelling something unknown is a clean false
        assert!(!c.cancel(4242));
        c.shutdown();
    }

    #[test]
    fn drop_shuts_down_cleanly() {
        let (c, _) = coordinator(73);
        let _ = c.generate(Request::greedy(1, vec![1], 2));
        drop(c); // must not hang
    }

    #[test]
    fn speculative_coordinator_matches_plain_generation() {
        let cfg = ModelConfig::tiny_gqa();
        let w = ModelWeights::init_vanilla(&cfg, 74);
        let want = greedy_generate(&w, &[2, 7, 1], 8);
        let c = Coordinator::spawn_speculative(
            CpuEngine::new(w.clone(), 8, 16 << 20),
            CpuEngine::new(crate::model::quantize(&w), 8, 16 << 20),
            SchedulerCfg {
                spec_k: 4,
                ..Default::default()
            },
        );
        let resp = c.generate(Request::greedy(1, vec![2, 7, 1], 8));
        assert_eq!(resp.tokens, want);
        use std::sync::atomic::Ordering;
        assert!(c.metrics().spec_rounds.load(Ordering::Relaxed) > 0);
        c.shutdown();
    }
}
