//! The engine abstraction the scheduler drives.
//!
//! An engine owns model weights and per-sequence KV state. The serving hot
//! loop drives ONE operation — [`Engine::step_batch`], the fused
//! continuous-batching step that advances decode rows and prefill-chunk
//! rows together — with `prefill`/`prefill_shared` (monolithic admission)
//! and `decode_batch` as the building blocks engines without chunked
//! support fall back to. The coordinator is engine-agnostic:
//! [`super::cpu_engine::CpuEngine`] runs the pure-Rust model against the
//! paged cache; [`crate::runtime::PjrtEngine`] runs the AOT-compiled JAX
//! artifacts through PJRT.

use crate::config::ModelConfig;
use crate::kvcache::{CacheSnapshot, SeqId};
use crate::tensor::Mat;
use std::fmt;

#[derive(Debug)]
pub enum EngineError {
    /// Not enough KV-cache capacity (caller should queue or preempt).
    CapacityExhausted(String),
    /// Sequence unknown or in a bad state.
    BadSequence(String),
    /// Backend failure (PJRT, artifact mismatch, ...).
    Backend(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::CapacityExhausted(m) => write!(f, "capacity exhausted: {m}"),
            EngineError::BadSequence(m) => write!(f, "bad sequence: {m}"),
            EngineError::Backend(m) => write!(f, "engine backend error: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// One sequence's decode input for a batched step.
#[derive(Clone, Copy, Debug)]
pub struct DecodeInput {
    pub seq: SeqId,
    /// The token sampled at the previous step (to be consumed now).
    pub token: u32,
}

/// One mid-prefill sequence's next prompt chunk for a fused
/// [`Engine::step_batch`]: consume `tokens` at the sequence's next prompt
/// positions. The scheduler sizes chunks from its per-step token budget;
/// the engine tracks how much of the prompt is already filled (from
/// [`Engine::prefill_begin`]).
#[derive(Clone, Debug)]
pub struct ChunkInput {
    pub seq: SeqId,
    pub tokens: Vec<u32>,
}

/// Result of one fused [`Engine::step_batch`].
#[derive(Debug, Default)]
pub struct StepOutput {
    /// One logits row per decode input, in order.
    pub decode_logits: Vec<Vec<f32>>,
    /// One entry per chunk input, in order: `Some(last-position logits)`
    /// exactly when that chunk completed its sequence's prompt.
    pub chunk_logits: Vec<Option<Vec<f32>>>,
}

/// Reusable output of [`Engine::step_batch_into`]: decode logits land in a
/// caller-owned matrix whose capacity survives across steps, so a
/// steady-state decode step writes results without touching the heap.
/// Chunk completions (rare, never steady-state) still allocate their rows.
#[derive(Debug, Default)]
pub struct StepOut {
    /// `(n_decodes, vocab)` — row `r` is decode input `r`'s logits.
    pub decode_logits: Mat,
    /// One entry per chunk input, in order: `Some(last-position logits)`
    /// exactly when that chunk completed its sequence's prompt.
    pub chunk_logits: Vec<Option<Vec<f32>>>,
}

/// Reusable output of [`Engine::verify_batch_into`]: all logits rows of the
/// widened step flattened into one matrix, with `row0[i]` the first row of
/// input `i` (input `i` owns rows `row0[i]..row0[i] + inputs[i].tokens.len()`).
#[derive(Debug, Default)]
pub struct VerifyOut {
    pub rows: Mat,
    pub row0: Vec<usize>,
}

/// Step-arena accounting, for engines that run the zero-allocation
/// steady-state path (`None` from everything else). Mirrored into the
/// `alloc.*` metrics gauges by the scheduler.
#[derive(Clone, Copy, Debug, Default)]
pub struct AllocStats {
    /// Bytes of reusable scratch the arena currently holds.
    pub arena_bytes: u64,
    /// Steps whose end-of-step arena footprint grew past the prior high
    /// water (expected 0 once warmed up).
    pub growth_events: u64,
}

/// One sequence's multi-position input for a widened verify step
/// ([`Engine::verify_batch`]): consume `tokens[0]`, `tokens[1]`, ... at
/// consecutive positions. In speculative decoding `tokens[0]` is the
/// committed next token and `tokens[1..]` is the draft continuation, so the
/// returned logits rows score every draft token plus one bonus position.
#[derive(Clone, Debug)]
pub struct VerifyInput {
    pub seq: SeqId,
    pub tokens: Vec<u32>,
}

/// Multi-engine parallelism counters, reported by engines that fan work
/// out across workers ([`super::sharded::ShardedEngine`]). `None` from
/// everything else; the scheduler mirrors these into metrics gauges only
/// when present, so the data-parallel router (which sets the gauges itself,
/// wrapping plain engines) is never clobbered.
#[derive(Clone, Copy, Debug)]
pub struct ShardStats {
    pub workers: usize,
    /// `"tp"` (tensor-parallel) or `"dp"` (data-parallel replicas).
    pub mode: &'static str,
    /// Cumulative fan-in/fan-out synchronizations (2 per layer per step in
    /// TP: gather attention outputs, broadcast the next block input).
    pub allreduce_calls: u64,
    /// Activation bytes crossing the shard boundary in those calls.
    pub allreduce_bytes: u64,
}

/// NB: not `Send`-bounded — PJRT client handles are `Rc`-based, so PJRT
/// engines are built *on* the coordinator thread via
/// [`crate::coordinator::Coordinator::spawn_with`].
pub trait Engine {
    fn cfg(&self) -> &ModelConfig;

    /// Human-readable identity for logs/metrics ("cpu/vanilla",
    /// "pjrt/merged_qp", ...).
    fn describe(&self) -> String;

    /// Can a prompt of this length be admitted right now?
    fn can_admit(&self, prompt_len: usize) -> bool;

    /// Max sequences a single decode batch may contain (PJRT engines are
    /// limited by their compiled bucket sizes; CPU is unbounded).
    fn max_batch(&self) -> usize;

    /// Admit + prefill a prompt. Returns the sequence id and the logits of
    /// the last prompt position (vocab-sized).
    fn prefill(&mut self, tokens: &[u32]) -> Result<(SeqId, Vec<f32>), EngineError>;

    /// Advance every sequence in `inputs` by one token. Returns one logits
    /// row per input, in order.
    fn decode_batch(&mut self, inputs: &[DecodeInput]) -> Result<Vec<Vec<f32>>, EngineError>;

    /// Release a finished/cancelled sequence's resources.
    fn release(&mut self, seq: SeqId);

    // ---- KV-block lifecycle (optional; defaults preserve the plain
    // prefill/recompute behavior for engines without a paged cache) -------

    /// Like [`Engine::can_admit`], but engines with a prefix index may
    /// credit blocks the concrete token prefix would reuse.
    fn can_admit_tokens(&self, tokens: &[u32]) -> bool {
        self.can_admit(tokens.len())
    }

    /// Prefill that may reuse already-cached prefix state. Returns the
    /// sequence id, last-position logits, and the number of leading prompt
    /// positions whose compute was skipped (0 for engines without sharing).
    fn prefill_shared(&mut self, tokens: &[u32]) -> Result<(SeqId, Vec<f32>, usize), EngineError> {
        self.prefill(tokens).map(|(seq, logits)| (seq, logits, 0))
    }

    /// Spill a running sequence's KV state so its blocks can serve others;
    /// the scheduler falls back to recompute-preemption when unsupported.
    fn swap_out(&mut self, _seq: SeqId) -> Result<(), EngineError> {
        Err(EngineError::Backend("swap not supported by this engine".into()))
    }

    /// Restore a sequence spilled by [`Engine::swap_out`], byte-identically.
    fn swap_in(&mut self, _seq: SeqId) -> Result<(), EngineError> {
        Err(EngineError::Backend("swap not supported by this engine".into()))
    }

    /// Would [`Engine::swap_in`] succeed now and still leave
    /// `headroom_blocks` KV blocks available?
    fn can_swap_in(&self, _seq: SeqId, _headroom_blocks: usize) -> bool {
        false
    }

    /// Paged-cache occupancy + lifecycle counters, if this engine has one.
    fn kv_snapshot(&self) -> Option<CacheSnapshot> {
        None
    }

    /// `(f32_equivalent, resident)` weight bytes — differ when the engine
    /// holds quantized weights. `(0, 0)` for engines that don't report.
    fn weight_bytes(&self) -> (u64, u64) {
        (0, 0)
    }

    // ---- chunked prefill / continuous batching (optional; engines
    // without support keep the monolithic admit-time prefill) -------------

    /// Can this engine run prefill in token-budgeted chunks
    /// ([`Engine::prefill_begin`] + [`Engine::step_batch`])? The scheduler
    /// falls back to monolithic [`Engine::prefill_shared`] admission when
    /// false.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Would this prompt reuse MORE cached prefix if admission waited for
    /// an in-flight chunked prefill to register further blocks? The
    /// scheduler defers such admissions one or more steps so that
    /// same-prefix prompts arriving together still share (with monolithic
    /// admission the earlier prefill completed inside `admit`, so later
    /// admissions probed a warm index for free — chunked admission has to
    /// ask).
    fn prefill_pending_prefix(&self, _tokens: &[u32]) -> bool {
        false
    }

    /// Begin a chunked admission: allocate a sequence for `tokens`
    /// (borrowing any cached shared prefix) **without computing anything**.
    /// Returns the sequence id and the number of leading prompt positions
    /// already filled from the prefix cache; the remaining positions are
    /// fed through [`Engine::step_batch`] chunk rows (or
    /// [`Engine::prefill_chunk`]) over subsequent steps. The engine reserves
    /// the prompt's KV blocks here, so admission capacity is identical to
    /// the monolithic path.
    fn prefill_begin(&mut self, _tokens: &[u32]) -> Result<(SeqId, usize), EngineError> {
        Err(EngineError::Backend(
            "chunked prefill not supported by this engine".into(),
        ))
    }

    /// Advance one mid-prefill sequence by one chunk of prompt tokens.
    /// Returns `Some(last-position logits)` exactly when this chunk
    /// completes the prompt. Chunked prefill must be **bit-identical** to a
    /// monolithic [`Engine::prefill_shared`] of the same prompt, for any
    /// chunk split. Default: one single-chunk fused step.
    fn prefill_chunk(
        &mut self,
        seq: SeqId,
        tokens: &[u32],
    ) -> Result<Option<Vec<f32>>, EngineError> {
        let out = self.step_batch(
            &[],
            &[ChunkInput {
                seq,
                tokens: tokens.to_vec(),
            }],
        )?;
        Ok(out.chunk_logits.into_iter().next().flatten())
    }

    /// THE fused continuous-batching step: advance every decode row by one
    /// token and every chunk row by its prompt chunk **through the same
    /// batched GEMMs and the same paged-attention grid**, so each weight
    /// matrix is streamed from memory once per step regardless of the
    /// phase mix. Decode rows must be bit-identical to
    /// [`Engine::decode_batch`] over the same inputs, and chunk rows
    /// bit-identical to a monolithic prefill (see
    /// [`Engine::prefill_chunk`]). Engines that cannot fuse keep the
    /// default, which handles pure-decode steps and rejects chunk rows.
    fn step_batch(
        &mut self,
        decodes: &[DecodeInput],
        chunks: &[ChunkInput],
    ) -> Result<StepOutput, EngineError> {
        if !chunks.is_empty() {
            return Err(EngineError::Backend(
                "chunked prefill not supported by this engine".into(),
            ));
        }
        Ok(StepOutput {
            decode_logits: self.decode_batch(decodes)?,
            chunk_logits: Vec::new(),
        })
    }

    // ---- speculative decoding (optional; defaults keep engines without
    // multi-position support correct, just unaccelerated) ----------------

    /// Advance each sequence by `tokens.len()` positions and return one
    /// logits row **per consumed token**, in order — the widened batched
    /// step of speculative decoding. Engines that override this must return
    /// rows bit-identical to what the same tokens fed one at a time through
    /// [`Engine::decode_batch`] would produce. Each row is the *full* logits
    /// distribution, not an argmax: greedy acceptance compares argmaxes
    /// (token-identical speculative output), while stochastic acceptance
    /// samples from each row with the request's own RNG — bit-identical rows
    /// are what upgrade that to *stream*-identical output versus plain
    /// decoding for a fixed seed. Implementations should fail *before*
    /// mutating any sequence state where possible — the scheduler
    /// defensively truncates back to the committed length after a capacity
    /// failure, but only rollback-capable engines can be repaired that way.
    /// The default implementation decodes sequentially — correct, but with
    /// no step-count reduction and no failure atomicity.
    fn verify_batch(&mut self, inputs: &[VerifyInput]) -> Result<Vec<Vec<Vec<f32>>>, EngineError> {
        let mut out = Vec::with_capacity(inputs.len());
        for vi in inputs {
            let mut rows = Vec::with_capacity(vi.tokens.len());
            for &token in &vi.tokens {
                let r = self.decode_batch(&[DecodeInput { seq: vi.seq, token }])?;
                rows.push(r.into_iter().next().expect("one row per input"));
            }
            out.push(rows);
        }
        Ok(out)
    }

    /// Roll a live sequence back to `new_len` positions, discarding the KV
    /// state of rejected draft positions. The scheduler only speculates on
    /// engines whose [`Engine::supports_rollback`] is true.
    fn truncate(&mut self, _seq: SeqId, _new_len: usize) -> Result<(), EngineError> {
        Err(EngineError::Backend("rollback not supported by this engine".into()))
    }

    /// Can this engine discard trailing positions ([`Engine::truncate`])?
    /// Speculative decoding requires it to reject draft tokens.
    fn supports_rollback(&self) -> bool {
        false
    }

    /// Multi-engine parallelism counters ([`ShardStats`]); `None` for
    /// single-engine backends.
    fn shard_stats(&self) -> Option<ShardStats> {
        None
    }

    // ---- zero-allocation steady state (optional; defaults delegate to
    // the allocating forms, so every engine stays correct) ----------------

    /// [`Engine::step_batch`] into caller-owned, capacity-reusing output.
    /// Engines with a step arena override this as the native path (zero
    /// heap allocations per steady-state decode step after warmup —
    /// `tests/alloc_regression.rs`); results are bit-identical to
    /// [`Engine::step_batch`] either way.
    fn step_batch_into(
        &mut self,
        decodes: &[DecodeInput],
        chunks: &[ChunkInput],
        out: &mut StepOut,
    ) -> Result<(), EngineError> {
        let r = self.step_batch(decodes, chunks)?;
        let vocab = r.decode_logits.first().map_or(0, Vec::len);
        out.decode_logits.reset(r.decode_logits.len(), vocab);
        for (i, row) in r.decode_logits.iter().enumerate() {
            out.decode_logits.row_mut(i).copy_from_slice(row);
        }
        out.chunk_logits = r.chunk_logits;
        Ok(())
    }

    /// [`Engine::verify_batch`] into caller-owned, capacity-reusing output
    /// (flattened rows + per-input start offsets). Bit-identical rows.
    fn verify_batch_into(
        &mut self,
        inputs: &[VerifyInput],
        out: &mut VerifyOut,
    ) -> Result<(), EngineError> {
        let nested = self.verify_batch(inputs)?;
        let total: usize = nested.iter().map(Vec::len).sum();
        let vocab = nested
            .iter()
            .find_map(|rows| rows.first().map(Vec::len))
            .unwrap_or(0);
        out.rows.reset(total, vocab);
        out.row0.clear();
        let mut r = 0usize;
        for rows in &nested {
            out.row0.push(r);
            for row in rows {
                out.rows.row_mut(r).copy_from_slice(row);
                r += 1;
            }
        }
        Ok(())
    }

    /// Step-arena accounting ([`AllocStats`]); `None` for engines without
    /// a zero-allocation steady-state path.
    fn alloc_stats(&self) -> Option<AllocStats> {
        None
    }

    /// Pre-reserve step-arena capacity for up to `max_rows` flattened rows
    /// per step (scheduler max batch × widest per-sequence row count) and
    /// `spec_k` draft tokens. Best-effort; a warmup step completes the
    /// sizing. No-op for engines without an arena.
    fn plan_alloc(&mut self, _max_rows: usize, _spec_k: usize) {}
}
