//! `skipless` CLI — leader entrypoint.
//!
//! Subcommands:
//! * `serve`    — boot coordinator + TCP JSON-lines server
//! * `generate` — one-shot generation from the command line
//! * `surgery`  — transform a vanilla weight file into a merged variant
//! * `init`     — create + save randomly-initialized vanilla weights
//! * `tables`   — print the paper's §3 table for any preset
//! * `audit`    — §4 invertibility/conditioning audit of a weight file
//! * `presets`  — list built-in model configs

use skipless::bandwidth::{self, Hardware};
use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{Coordinator, CpuEngine, Request, SchedulerCfg};
use skipless::model::{weights_io, ModelWeights};
use skipless::params;
use skipless::runtime::PjrtEngine;
use skipless::sampler::grammar::Constraint;
use skipless::sampler::SamplerCfg;
use skipless::server::{Server, ServerCfg};
use skipless::surgery;
use skipless::util::cli::Command;
use skipless::util::logging::{self, Level};
use std::path::{Path, PathBuf};

fn cli() -> Command {
    Command::new("skipless", "KV-weights are all you need for skipless transformers")
        .subcommand(
            Command::new("serve", "serve a model over TCP (JSON lines)")
                .opt_default("addr", "127.0.0.1:7070", "bind address")
                .opt("weights", "weight file (.swt) — or use --preset for random init")
                .opt_default("preset", "tiny-gqa", "config preset when no weights given")
                .opt_default("variant", "vanilla", "vanilla|merged_qp|merged_kp|merged_vp")
                .opt("artifacts", "artifact dir → use the PJRT engine (else CPU engine)")
                .opt_default("seed", "1", "init seed when no weights given")
                .opt_default("cache-mb", "256", "KV cache budget (MiB, CPU engine)")
                .opt_default("max-running", "32", "max concurrent sequences")
                .opt_default(
                    "token-budget",
                    "2048",
                    "per-step token budget: decode rows first, rest fills prefill chunks",
                )
                .opt_default(
                    "chunk-tokens",
                    "256",
                    "max prompt tokens one sequence prefills per step (chunked prefill)",
                )
                .flag("no-prefix-cache", "disable automatic prefix sharing (CPU engine)")
                .opt_default("quantize", "none", "weights: none|int8 (per-channel symmetric)")
                .flag("quantize-kv", "u8 KV-cache blocks: ~4x tokens per budget (CPU engine)")
                .opt_default(
                    "speculate",
                    "0",
                    "self-speculative decode: int8 draft proposes k tokens/step (CPU engine)",
                )
                .opt_default(
                    "workers",
                    "1",
                    "engines behind the coordinator (CPU engine; see --parallel)",
                )
                .opt_default(
                    "parallel",
                    "tp",
                    "multi-engine mode for --workers N: tp = tensor-parallel KV-head-group \
                     sharding (bit-identical output), dp = replicated engines behind a \
                     prefix-cache-aware router",
                )
                .opt_default("max-conns", "1024", "connection ceiling; excess accepts refused")
                .opt_default(
                    "rate-limit",
                    "0",
                    "per-client-IP generate ops/sec (token bucket; 0 = unlimited)",
                )
                .opt_default(
                    "queue-depth",
                    "256",
                    "in-flight generate ceiling; excess sheds with error=overloaded",
                )
                .opt_default("log", "info", "log level"),
        )
        .subcommand(
            Command::new("generate", "one-shot generation")
                .opt("weights", "weight file (.swt)")
                .opt_default("preset", "tiny-gqa", "config preset when no weights given")
                .opt_default("variant", "vanilla", "architecture variant")
                .opt_default("seed", "1", "init seed when no weights given")
                .opt_default("prompt", "1,2,3", "comma-separated token ids")
                .opt_default("max-new", "16", "tokens to generate")
                .opt_default("temperature", "0", "sampling temperature (0 = greedy)")
                .opt_default(
                    "chunk-tokens",
                    "256",
                    "max prompt tokens prefilled per step (chunked prefill)",
                )
                .opt_default("quantize", "none", "weights: none|int8 (per-channel symmetric)")
                .flag("quantize-kv", "u8 KV-cache blocks: ~4x tokens per budget")
                .opt_default(
                    "speculate",
                    "0",
                    "self-speculative decode: int8 draft proposes k tokens/step (f32 weights)",
                )
                .opt_default(
                    "constrain",
                    "none",
                    "grammar-constrain the output: none|json (byte-level mask; \
                     the completion is guaranteed to parse)",
                ),
        )
        .subcommand(
            Command::new("init", "write randomly-initialized vanilla weights")
                .opt_default("preset", "tiny-gqa", "config preset")
                .opt_default("seed", "1", "init seed")
                .opt("out", "output path (.swt)"),
        )
        .subcommand(
            Command::new("surgery", "paper Table 1: merge weights (removes Q+P etc.)")
                .opt("weights", "input vanilla weight file (.swt)")
                .opt_default("variant", "merged_qp", "merged_qp|merged_kp|merged_vp")
                .opt("out", "output path (.swt)")
                .opt_default("cond-limit", "1e7", "max pivot condition number")
                .opt_default("quantize", "none", "weights: none|int8 (applied after the merge)")
                .flag("verify", "run a logits-equivalence check after merging"),
        )
        .subcommand(
            Command::new("tables", "print the paper's §3 table")
                .opt("preset", "one preset (default: both paper models)"),
        )
        .subcommand(
            Command::new("audit", "§4 invertibility audit of attention matrices")
                .opt("weights", "weight file (.swt); default: random preset weights")
                .opt_default("preset", "tiny-mha", "preset when no weights given")
                .opt_default("variant", "vanilla", "architecture variant")
                .opt_default("seed", "1", "init seed"),
        )
        .subcommand(Command::new("presets", "list built-in model configs"))
}

fn main() {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (path, args) = match cli().parse(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match path.first().copied() {
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("init") => cmd_init(&args),
        Some("surgery") => cmd_surgery(&args),
        Some("tables") => cmd_tables(&args),
        Some("audit") => cmd_audit(&args),
        Some("presets") => {
            for p in ModelConfig::preset_names() {
                let c = ModelConfig::preset(p).unwrap();
                println!(
                    "{:<14} d={:<5} L={:<3} heads={}/{} f={:<6} vocab={:<6} {}/{}/{}",
                    p, c.dim, c.n_layers, c.n_heads, c.n_kv_heads, c.hidden_dim,
                    c.vocab_size, c.attention.name(), c.layout.name(), c.ffn.name()
                );
            }
            Ok(())
        }
        _ => {
            println!("{}", cli().help_text());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

type AnyError = Box<dyn std::error::Error>;

fn load_or_init(args: &skipless::util::cli::Args) -> Result<ModelWeights, AnyError> {
    if let Some(path) = args.get("weights") {
        let w = weights_io::load(Path::new(path))?;
        log_summary(&w);
        return Ok(w);
    }
    let preset = args.get_or("preset", "tiny-gqa");
    let cfg = ModelConfig::load(preset)?;
    let seed: u64 = args.num_or("seed", 1)?;
    let variant = Variant::parse(args.get_or("variant", "vanilla"))
        .ok_or_else(|| format!("bad variant '{}'", args.get_or("variant", "")))?;
    let w = ModelWeights::init_vanilla(&cfg, seed);
    let w = if variant == Variant::Vanilla {
        w
    } else {
        surgery::transform(&w, variant, surgery::Options::default())?
    };
    log_summary(&w);
    Ok(w)
}

fn log_summary(w: &ModelWeights) {
    skipless::log_info!(
        "model {} [{}{}]: {} weights ({:.1} MiB resident, {:.1} MiB at f32)",
        w.cfg.name,
        w.variant.name(),
        if w.is_quantized() { "/int8" } else { "" },
        w.stored_weights(),
        w.resident_bytes() as f64 / (1 << 20) as f64,
        w.stored_bytes() as f64 / (1 << 20) as f64
    );
}

/// Apply `--quantize` (after any surgery — the passes only compose that
/// way; see DESIGN.md §Quantization).
fn apply_quantize(
    args: &skipless::util::cli::Args,
    w: ModelWeights,
) -> Result<ModelWeights, AnyError> {
    match args.get_or("quantize", "none") {
        "none" | "f32" => Ok(w),
        "int8" => {
            let q = skipless::model::quantize(&w);
            log_summary(&q);
            Ok(q)
        }
        other => Err(format!("bad --quantize '{other}' (expected none|int8)").into()),
    }
}

fn cmd_serve(args: &skipless::util::cli::Args) -> Result<(), AnyError> {
    if let Some(l) = Level::parse(args.get_or("log", "info")) {
        logging::set_level(l);
    }
    // Fail before boot, not inside the coordinator thread: the PJRT
    // artifacts are lowered for f32 weights and an f32 KV layout.
    if args.get("artifacts").is_some()
        && (!matches!(args.get_or("quantize", "none"), "none" | "f32") || args.flag("quantize-kv"))
    {
        return Err(
            "the PJRT engine (--artifacts) is f32-only; drop --quantize/--quantize-kv \
             or serve on the CPU engine"
                .into(),
        );
    }
    let spec_k: usize = args.num_or("speculate", 0)?;
    if spec_k > 0 && args.get("artifacts").is_some() {
        return Err("--speculate requires the CPU engine (drop --artifacts)".into());
    }
    let workers: usize = args.num_or("workers", 1)?;
    if workers == 0 {
        return Err("--workers must be >= 1".into());
    }
    let parallel = args.get_or("parallel", "tp");
    if !matches!(parallel, "tp" | "dp") {
        return Err(format!("bad --parallel '{parallel}' (expected tp|dp)").into());
    }
    if workers > 1 {
        if args.get("artifacts").is_some() {
            return Err("--workers > 1 requires the CPU engine (drop --artifacts)".into());
        }
        if parallel == "tp" && args.flag("quantize-kv") {
            return Err(
                "tensor-parallel sharding needs an f32 KV pool; drop --quantize-kv \
                 or use --parallel dp"
                    .into(),
            );
        }
        if parallel == "dp" && spec_k > 0 {
            return Err(
                "--parallel dp does not compose with --speculate (each replica would \
                 need its own draft); use --parallel tp"
                    .into(),
            );
        }
    }
    let w = apply_quantize(args, load_or_init(args)?)?;
    if spec_k > 0 && w.is_quantized() {
        return Err(
            "--speculate drafts with an int8 copy built from f32 target weights; \
             drop --quantize (the draft is quantized automatically)"
                .into(),
        );
    }
    let sched = SchedulerCfg {
        max_running: args.num_or("max-running", 32)?,
        token_budget_per_step: args.num_or("token-budget", 2048)?,
        chunk_tokens: args.num_or("chunk-tokens", 256)?,
        spec_k,
    };
    let coordinator = if let Some(dir) = args.get("artifacts") {
        // Also catches quantized .swt files loaded via --weights, which the
        // flag guard above cannot see.
        if w.is_quantized() {
            return Err(
                "the PJRT engine (--artifacts) is f32-only; these weights are int8 — \
                 serve them on the CPU engine"
                    .into(),
            );
        }
        let dir = PathBuf::from(dir);
        Coordinator::spawn_with(move || PjrtEngine::boot(&dir, &w, 64).expect("pjrt boot"), sched)
    } else {
        let cache_mb: usize = args.num_or("cache-mb", 256)?;
        let opts = skipless::kvcache::CacheOpts {
            prefix_sharing: !args.flag("no-prefix-cache"),
            quantized: args.flag("quantize-kv"),
            ..Default::default()
        };
        if workers > 1 && parallel == "dp" {
            // replicated engines: the budget splits evenly; the router keeps
            // repeated prompts on the replica whose cache already has them
            let per_budget = (cache_mb << 20) / workers;
            skipless::log_info!(
                "data-parallel: {workers} replicas, {} MiB KV budget each",
                per_budget >> 20
            );
            Coordinator::spawn_replicated(
                move |_| CpuEngine::with_cache_opts(w.clone(), 16, per_budget, opts),
                workers,
                16,
                sched,
            )
        } else if workers > 1 {
            // tensor-parallel: one engine, weights sharded by KV-head group
            // — output stays bit-identical to single-engine serving
            let dw = (spec_k > 0).then(|| skipless::model::quantize(&w));
            let target = skipless::coordinator::ShardedEngine::with_cache_opts(
                w,
                workers,
                16,
                cache_mb << 20,
                opts,
            )
            .map_err(|e| format!("--workers {workers} (tensor-parallel): {e}"))?;
            skipless::log_info!("tensor-parallel: {workers} shard workers");
            match dw {
                Some(dw) => {
                    let draft_opts = skipless::kvcache::CacheOpts {
                        prefix_sharing: true,
                        quantized: true,
                        ..Default::default()
                    };
                    let draft = CpuEngine::with_cache_opts(dw, 16, cache_mb << 20, draft_opts);
                    Coordinator::spawn_speculative(target, draft, sched)
                }
                None => Coordinator::spawn(target, sched),
            }
        } else if spec_k > 0 {
            // self-speculation: the int8 copy drafts, the f32 target
            // verifies — token-identical greedy output (DESIGN.md
            // §Speculative). The draft gets its own u8-KV pool: draft
            // precision never affects correctness, only accept rate.
            let draft_opts = skipless::kvcache::CacheOpts {
                prefix_sharing: true,
                quantized: true,
                ..Default::default()
            };
            let dw = skipless::model::quantize(&w);
            let draft = CpuEngine::with_cache_opts(dw, 16, cache_mb << 20, draft_opts);
            Coordinator::spawn_speculative(
                CpuEngine::with_cache_opts(w, 16, cache_mb << 20, opts),
                draft,
                sched,
            )
        } else {
            Coordinator::spawn(
                CpuEngine::with_cache_opts(w, 16, cache_mb << 20, opts),
                sched,
            )
        }
    };
    let server_cfg = ServerCfg {
        max_conns: args.num_or("max-conns", 1024)?,
        rate_limit: args.num_or("rate-limit", 0.0f64)?,
        queue_depth: args.num_or("queue-depth", 256)?,
        ..Default::default()
    };
    let server = Server::bind_with(args.get_or("addr", "127.0.0.1:7070"), coordinator, server_cfg)?;
    println!(
        "listening on {} (JSON lines; op=generate|metrics|ping; \
         generate accepts \"stream\":true)",
        server.local_addr()
    );
    server.serve()?;
    Ok(())
}

fn cmd_generate(args: &skipless::util::cli::Args) -> Result<(), AnyError> {
    let w = apply_quantize(args, load_or_init(args)?)?;
    let spec_k: usize = args.num_or("speculate", 0)?;
    if spec_k > 0 && w.is_quantized() {
        return Err(
            "--speculate drafts with an int8 copy built from f32 target weights; \
             drop --quantize (the draft is quantized automatically)"
                .into(),
        );
    }
    let prompt: Vec<u32> = args
        .get_or("prompt", "1,2,3")
        .split(',')
        .map(|t| t.trim().parse::<u32>())
        .collect::<Result<_, _>>()?;
    let opts = skipless::kvcache::CacheOpts {
        quantized: args.flag("quantize-kv"),
        ..Default::default()
    };
    let sched = SchedulerCfg {
        spec_k,
        chunk_tokens: args.num_or("chunk-tokens", 256)?,
        ..Default::default()
    };
    let coordinator = if spec_k > 0 {
        let draft_opts = skipless::kvcache::CacheOpts {
            quantized: true,
            ..Default::default()
        };
        let draft =
            CpuEngine::with_cache_opts(skipless::model::quantize(&w), 16, 256 << 20, draft_opts);
        Coordinator::spawn_speculative(
            CpuEngine::with_cache_opts(w, 16, 256 << 20, opts),
            draft,
            sched,
        )
    } else {
        Coordinator::spawn(CpuEngine::with_cache_opts(w, 16, 256 << 20, opts), sched)
    };
    let constrain = match args.get_or("constrain", "none") {
        "none" => None,
        s => match Constraint::parse(s) {
            Some(g) => Some(g),
            None => return Err(format!("--constrain {s}: expected none|json").into()),
        },
    };
    let req = Request {
        id: 0,
        prompt,
        max_new_tokens: args.num_or("max-new", 16)?,
        sampler: SamplerCfg {
            temperature: args.num_or("temperature", 0.0f32)?,
            top_k: 0,
            top_p: 1.0,
        },
        seed: 0,
        eos: None,
        constrain,
    };
    let resp = coordinator.generate(req);
    println!(
        "tokens: {:?}\nfinish: {:?}  ttft: {:?}  latency: {:?}",
        resp.tokens, resp.finish, resp.ttft, resp.latency
    );
    if constrain.is_some() {
        // byte-vocab: ids <= 255 decode directly to the generated document
        let bytes: Vec<u8> = resp.tokens.iter().filter_map(|&t| u8::try_from(t).ok()).collect();
        println!("text: {}", String::from_utf8_lossy(&bytes));
    }
    if spec_k > 0 {
        use std::sync::atomic::Ordering;
        let m = coordinator.metrics();
        println!(
            "speculative: {} rounds, {}/{} drafts accepted ({:.0}%)",
            m.spec_rounds.load(Ordering::Relaxed),
            m.spec_tokens_accepted.load(Ordering::Relaxed),
            m.spec_tokens_drafted.load(Ordering::Relaxed),
            100.0 * m.spec_accept_rate()
        );
    }
    coordinator.shutdown();
    Ok(())
}

fn cmd_init(args: &skipless::util::cli::Args) -> Result<(), AnyError> {
    let preset = args.get_or("preset", "tiny-gqa");
    let cfg = ModelConfig::load(preset)?;
    let seed: u64 = args.num_or("seed", 1)?;
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{preset}.swt")));
    let w = ModelWeights::init_vanilla(&cfg, seed);
    weights_io::save(&w, &out)?;
    println!(
        "wrote {} ({} weights, {:.1} MiB)",
        out.display(),
        w.stored_weights(),
        w.stored_bytes() as f64 / (1 << 20) as f64
    );
    Ok(())
}

fn cmd_surgery(args: &skipless::util::cli::Args) -> Result<(), AnyError> {
    let input = args.get("weights").ok_or("--weights required")?;
    let variant = Variant::parse(args.get_or("variant", "merged_qp")).ok_or("bad variant")?;
    let w = weights_io::load(Path::new(input))?;
    let opts = surgery::Options {
        cond_limit: args.num_or("cond-limit", surgery::DEFAULT_COND_LIMIT)?,
        skip_audit: false,
    };
    let t0 = std::time::Instant::now();
    let merged = surgery::transform(&w, variant, opts)?;
    let dt = t0.elapsed();
    // The equivalence check verifies the exact f32 algebra, so it runs on
    // the merged weights BEFORE any --quantize int8 (whose ~1% drift is a
    // property of quantization, not of the merge).
    if args.flag("verify") {
        let toks = [1u32, 2, 3, 4, 5];
        let (l0, _) = skipless::model::prefill(&w, &toks);
        let (l1, _) = skipless::model::prefill(&merged, &toks);
        let rel = l1.rel_fro_err(&l0);
        println!("verification: relative logits error = {rel:.3e}");
        if rel > 1e-3 {
            return Err(format!("verification FAILED: rel err {rel:.3e} > 1e-3").into());
        }
    }
    let merged = apply_quantize(args, merged)?;
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            PathBuf::from(input.replace(".swt", &format!(".{}.swt", variant.name())))
        });
    weights_io::save(&merged, &out)?;
    let saved = w.stored_weights() - merged.stored_weights();
    println!(
        "surgery [{}{}] in {:?}: {} → {} weights (−{}, −{:.1}%)\nwrote {}",
        variant.name(),
        if merged.is_quantized() { "/int8" } else { "" },
        dt,
        w.stored_weights(),
        merged.stored_weights(),
        saved,
        100.0 * saved as f64 / w.stored_weights() as f64,
        out.display()
    );
    Ok(())
}

fn cmd_tables(args: &skipless::util::cli::Args) -> Result<(), AnyError> {
    let presets: Vec<String> = match args.get("preset") {
        Some(p) => vec![p.to_string()],
        None => vec!["pythia-6.9b".into(), "mistral-7b".into()],
    };
    println!("== paper §3 table (weight counts & batch-1 bandwidth-bound speedup) ==\n");
    for p in presets {
        let cfg = ModelConfig::load(&p)?;
        print!("{}", params::table3_report(&cfg));
        let hw = Hardware::a100_like();
        let cross = bandwidth::compute_bound_batch(&cfg, &hw, 2.0);
        println!(
            "  Roofline ({}, fp16)   : compute-bound above batch ≈ {}\n",
            hw.name, cross
        );
    }
    Ok(())
}

fn cmd_audit(args: &skipless::util::cli::Args) -> Result<(), AnyError> {
    let w = load_or_init(args)?;
    let rows = surgery::audit(&w);
    println!("layer  matrix  invertible  cond_estimate");
    for r in &rows {
        println!(
            "{:>5}  {:>6}  {:>10}  {}",
            r.layer,
            r.which,
            r.invertible,
            r.cond.map(|c| format!("{c:.3e}")).unwrap_or_else(|| "-".into())
        );
    }
    let (all, worst) = surgery::audit_summary(&rows);
    println!(
        "\nall invertible: {all}   worst κ₁ ≈ {worst:.3e}   ({} matrices)",
        rows.len()
    );
    Ok(())
}
