//! Serving metrics: counters, gauges, and latency histograms.
//!
//! Thread-safe via atomics; histograms use log-spaced buckets so p50/p95/p99
//! stay accurate from microseconds to seconds without unbounded memory.
//! The coordinator exposes a registry snapshot as JSON over the server's
//! `metrics` endpoint.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency histogram: 1µs .. ~17min in 64 buckets (×1.5 steps).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const N_BUCKETS: usize = 64;
const GROWTH: f64 = 1.5;

fn bucket_of(us: u64) -> usize {
    if us <= 1 {
        return 0;
    }
    let b = ((us as f64).ln() / GROWTH.ln()) as usize;
    b.min(N_BUCKETS - 1)
}

/// Upper bound (µs) of bucket `i`.
fn bucket_upper(i: usize) -> u64 {
    GROWTH.powi(i as i32 + 1) as u64
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Quantile from the histogram (upper bound of the containing bucket).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let max_us = self.max_us.load(Ordering::Relaxed);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                // clamp the bucket's upper bound to the observed max so
                // quantile(q) ≤ max() always holds
                return Duration::from_micros(bucket_upper(i).min(max_us));
            }
        }
        self.max()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("mean_us", Json::num(self.mean().as_micros() as f64)),
            ("p50_us", Json::num(self.quantile(0.50).as_micros() as f64)),
            ("p95_us", Json::num(self.quantile(0.95).as_micros() as f64)),
            ("p99_us", Json::num(self.quantile(0.99).as_micros() as f64)),
            ("max_us", Json::num(self.max().as_micros() as f64)),
        ])
    }
}

/// All serving metrics, shared by reference across the coordinator.
///
/// The `kv_*` family mirrors the engine's paged-cache lifecycle
/// ([`crate::kvcache::CacheStats`] plus occupancy gauges): the scheduler
/// refreshes them after every step, and the server publishes the whole
/// registry — including a nested `kv_cache` object — under
/// `{"op":"metrics"}`.
#[derive(Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    /// Requests retired by [`crate::coordinator::Scheduler::cancel`] —
    /// admitted + cancelled + completed + rejected stays conserved.
    pub requests_cancelled: AtomicU64,
    /// Prompt positions actually computed by prefill (shared-prefix
    /// positions are counted in [`Metrics::kv_prefix_tokens_saved`] instead).
    pub tokens_prefilled: AtomicU64,
    pub tokens_decoded: AtomicU64,
    pub batches_run: AtomicU64,
    /// Preemption events of either kind (swap-out or recompute).
    pub preemptions: AtomicU64,
    // -- continuous batching / chunked prefill ---------------------------
    /// Prefill chunks executed through the fused step.
    pub prefill_chunks: AtomicU64,
    /// Prompt tokens computed via prefill chunks (a subset of
    /// [`Metrics::tokens_prefilled`], which also counts monolithic
    /// admissions on engines without chunked support).
    pub prefill_chunk_tokens: AtomicU64,
    /// The scheduler's per-step token budget (gauge).
    pub budget_token_limit: AtomicU64,
    /// Tokens planned into the most recent step — decode rows plus prompt
    /// chunk tokens (gauge; utilization = planned / limit).
    pub budget_tokens_planned: AtomicU64,
    // -- KV-block lifecycle (mirrored from the engine's cache) -----------
    pub kv_prefix_hit_blocks: AtomicU64,
    pub kv_prefix_tokens_saved: AtomicU64,
    pub kv_cow_copies: AtomicU64,
    pub kv_evictions: AtomicU64,
    pub kv_swap_outs: AtomicU64,
    pub kv_swap_ins: AtomicU64,
    pub kv_swap_blocks_reused: AtomicU64,
    pub kv_blocks_used: AtomicU64,
    pub kv_blocks_free: AtomicU64,
    pub kv_blocks_cached: AtomicU64,
    pub kv_swapped_seqs: AtomicU64,
    pub kv_swapped_blocks: AtomicU64,
    /// Live blocks held in u8 quantized form (0 on an f32 pool).
    pub kv_quantized_blocks: AtomicU64,
    /// Bytes per cached token at the pool's precision.
    pub kv_bytes_per_token: AtomicU64,
    /// Positions rolled back by [`crate::kvcache::KvCache::truncate_seq`]
    /// (rejected speculative draft positions).
    pub kv_truncated_positions: AtomicU64,
    // -- paged attention (zero-copy KV reads) ----------------------------
    /// Bytes of K/V the attention kernel read **in place** from the paged
    /// pool (pool precision, incl. u8 quantization meta).
    pub attn_paged_reads_bytes: AtomicU64,
    /// f32 scratch bytes the old gather path would have memcpy'd for those
    /// same reads — copy traffic the zero-copy path avoided.
    pub attn_gather_bytes_avoided: AtomicU64,
    /// [`crate::kvcache::KvCache::gather`] calls. The steady-state decode
    /// path reads in place, so serving keeps this at 0 — benches and the
    /// serving regression test assert it.
    pub attn_gather_calls: AtomicU64,
    // -- quantization (weights side) -------------------------------------
    /// Bytes the weights would occupy at f32.
    pub weight_bytes_f32: AtomicU64,
    /// Bytes the weights actually occupy resident.
    pub weight_bytes_resident: AtomicU64,
    // -- speculative decoding --------------------------------------------
    /// Widened verify rounds, one per (sequence, verify-step) pair.
    pub spec_rounds: AtomicU64,
    /// Draft-engine batched decode steps spent producing drafts.
    pub spec_draft_steps: AtomicU64,
    /// Draft tokens proposed to the target.
    pub spec_tokens_drafted: AtomicU64,
    /// Draft tokens the target accepted (greedy rule).
    pub spec_tokens_accepted: AtomicU64,
    /// Spec-eligible rounds that fell back to plain decode (draft admission
    /// or capacity trouble).
    pub spec_fallbacks: AtomicU64,
    /// Requests whose drafting was turned off for losing (adaptive policy).
    pub spec_disabled: AtomicU64,
    // -- multi-engine parallelism ----------------------------------------
    /// Worker engines behind the coordinator (gauge; 1 when unsharded).
    pub shard_workers: AtomicU64,
    /// Parallelism mode (gauge): 0 = off, 1 = tensor-parallel, 2 =
    /// data-parallel (rendered as a string in the JSON).
    pub shard_mode: AtomicU64,
    /// TP fan-in/fan-out synchronizations (2 per layer per step).
    pub shard_allreduce_calls: AtomicU64,
    /// Activation bytes crossing the shard boundary in those calls.
    pub shard_allreduce_bytes: AtomicU64,
    /// DP router submits placed on a replica that already holds the
    /// request's longest cached prompt prefix.
    pub shard_router_prefix_hits: AtomicU64,
    // -- step-arena allocation discipline ---------------------------------
    /// Bytes of reusable step-arena scratch the engine holds (gauge,
    /// mirrored from [`crate::coordinator::engine::Engine::alloc_stats`]).
    pub alloc_arena_bytes: AtomicU64,
    /// Steps whose arena grew past its warmed-up high water (gauge;
    /// expected 0 in steady state — the warmup-then-zero invariant).
    pub alloc_steady_state_allocs: AtomicU64,
    // -- serving front-end (reactor) -------------------------------------
    /// Currently-open client connections (gauge).
    pub conns_open: AtomicU64,
    /// Connections ever accepted.
    pub conns_accepted: AtomicU64,
    /// Connections refused at accept time (`--max-conns` ceiling).
    pub conns_rejected: AtomicU64,
    /// Generate requests refused with `{"error":"overloaded"}` because the
    /// admission queue was at `--queue-depth`.
    pub requests_shed: AtomicU64,
    /// Generate requests refused with `{"error":"rate_limited"}` by the
    /// per-client token bucket (`--rate-limit`).
    pub requests_rate_limited: AtomicU64,
    /// Generate requests that asked for `"stream":true`.
    pub stream_requests: AtomicU64,
    /// `{"event":"token"}` frames actually enqueued to clients.
    pub stream_tokens_sent: AtomicU64,
    /// Bytes currently queued across all per-connection write queues
    /// (gauge) — the reactor's total buffered-output footprint.
    pub write_queue_bytes: AtomicU64,
    /// High-water mark of any single connection's write queue (gauge via
    /// `fetch_max`); backpressure keeps this ≤ cap + one frame.
    pub write_queue_peak_bytes: AtomicU64,
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub e2e: Histogram,
    /// Time-to-first-byte as the *server* observes it: generate accepted →
    /// first reply frame (token frame or final object) enqueued to the
    /// connection's write queue.
    pub ttfb: Histogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite a gauge (used when mirroring engine-side counters).
    pub fn set(gauge: &AtomicU64, v: u64) {
        gauge.store(v, Ordering::Relaxed);
    }

    /// Fraction of drafted tokens the target accepted.
    pub fn spec_accept_rate(&self) -> f64 {
        let drafted = self.spec_tokens_drafted.load(Ordering::Relaxed) as f64;
        if drafted == 0.0 {
            0.0
        } else {
            self.spec_tokens_accepted.load(Ordering::Relaxed) as f64 / drafted
        }
    }

    /// Fraction of the last step's token budget actually planned.
    pub fn budget_utilization(&self) -> f64 {
        let limit = self.budget_token_limit.load(Ordering::Relaxed) as f64;
        if limit == 0.0 {
            0.0
        } else {
            self.budget_tokens_planned.load(Ordering::Relaxed) as f64 / limit
        }
    }

    /// Fraction of prompt tokens served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        let saved = self.kv_prefix_tokens_saved.load(Ordering::Relaxed) as f64;
        let computed = self.tokens_prefilled.load(Ordering::Relaxed) as f64;
        if saved + computed == 0.0 {
            0.0
        } else {
            saved / (saved + computed)
        }
    }

    pub fn to_json(&self) -> Json {
        let g = |c: &AtomicU64| Json::num(c.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("requests_admitted", g(&self.requests_admitted)),
            ("requests_completed", g(&self.requests_completed)),
            ("requests_rejected", g(&self.requests_rejected)),
            ("requests_cancelled", g(&self.requests_cancelled)),
            ("tokens_prefilled", g(&self.tokens_prefilled)),
            ("tokens_decoded", g(&self.tokens_decoded)),
            ("batches_run", g(&self.batches_run)),
            ("preemptions", g(&self.preemptions)),
            // process-wide kernel dispatch gauge (avx2/neon/scalar)
            ("simd_dispatch", Json::str(crate::linalg::simd::level_name())),
            (
                "prefill",
                Json::obj(vec![
                    ("chunks", g(&self.prefill_chunks)),
                    ("chunk_tokens", g(&self.prefill_chunk_tokens)),
                ]),
            ),
            (
                "budget",
                Json::obj(vec![
                    ("token_limit", g(&self.budget_token_limit)),
                    ("tokens_planned", g(&self.budget_tokens_planned)),
                    ("utilization", Json::num(self.budget_utilization())),
                ]),
            ),
            (
                "kv_cache",
                Json::obj(vec![
                    ("prefix_hit_blocks", g(&self.kv_prefix_hit_blocks)),
                    ("prefix_tokens_saved", g(&self.kv_prefix_tokens_saved)),
                    ("prefix_hit_rate", Json::num(self.prefix_hit_rate())),
                    ("cow_copies", g(&self.kv_cow_copies)),
                    ("evictions", g(&self.kv_evictions)),
                    ("swap_outs", g(&self.kv_swap_outs)),
                    ("swap_ins", g(&self.kv_swap_ins)),
                    ("swap_blocks_reused", g(&self.kv_swap_blocks_reused)),
                    ("blocks_used", g(&self.kv_blocks_used)),
                    ("blocks_free", g(&self.kv_blocks_free)),
                    ("blocks_cached", g(&self.kv_blocks_cached)),
                    ("swapped_seqs", g(&self.kv_swapped_seqs)),
                    ("swapped_blocks", g(&self.kv_swapped_blocks)),
                    ("quantized_blocks", g(&self.kv_quantized_blocks)),
                    ("bytes_per_token", g(&self.kv_bytes_per_token)),
                    ("truncated_positions", g(&self.kv_truncated_positions)),
                ]),
            ),
            (
                "attn",
                Json::obj(vec![
                    ("paged_reads_bytes", g(&self.attn_paged_reads_bytes)),
                    ("gather_bytes_avoided", g(&self.attn_gather_bytes_avoided)),
                    ("gather_calls", g(&self.attn_gather_calls)),
                ]),
            ),
            (
                "speculative",
                Json::obj(vec![
                    ("rounds", g(&self.spec_rounds)),
                    ("draft_steps", g(&self.spec_draft_steps)),
                    ("tokens_drafted", g(&self.spec_tokens_drafted)),
                    ("tokens_accepted", g(&self.spec_tokens_accepted)),
                    ("accept_rate", Json::num(self.spec_accept_rate())),
                    ("fallbacks", g(&self.spec_fallbacks)),
                    ("disabled", g(&self.spec_disabled)),
                ]),
            ),
            (
                "quant",
                Json::obj(vec![
                    ("weight_bytes_f32", g(&self.weight_bytes_f32)),
                    ("weight_bytes_resident", g(&self.weight_bytes_resident)),
                    (
                        "weight_bytes_saved",
                        Json::num(
                            self.weight_bytes_f32
                                .load(Ordering::Relaxed)
                                .saturating_sub(self.weight_bytes_resident.load(Ordering::Relaxed))
                                as f64,
                        ),
                    ),
                ]),
            ),
            (
                "shard",
                Json::obj(vec![
                    ("workers", g(&self.shard_workers)),
                    (
                        "mode",
                        Json::str(match self.shard_mode.load(Ordering::Relaxed) {
                            1 => "tp",
                            2 => "dp",
                            _ => "off",
                        }),
                    ),
                    ("allreduce_calls", g(&self.shard_allreduce_calls)),
                    ("allreduce_bytes", g(&self.shard_allreduce_bytes)),
                    ("router_prefix_hits", g(&self.shard_router_prefix_hits)),
                ]),
            ),
            (
                "alloc",
                Json::obj(vec![
                    ("arena_bytes", g(&self.alloc_arena_bytes)),
                    ("steady_state_allocs", g(&self.alloc_steady_state_allocs)),
                ]),
            ),
            (
                "server",
                Json::obj(vec![
                    ("conns_open", g(&self.conns_open)),
                    ("conns_accepted", g(&self.conns_accepted)),
                    ("conns_rejected", g(&self.conns_rejected)),
                    ("requests_shed", g(&self.requests_shed)),
                    ("requests_rate_limited", g(&self.requests_rate_limited)),
                    ("stream_requests", g(&self.stream_requests)),
                    ("stream_tokens_sent", g(&self.stream_tokens_sent)),
                    ("write_queue_bytes", g(&self.write_queue_bytes)),
                    ("write_queue_peak_bytes", g(&self.write_queue_peak_bytes)),
                ]),
            ),
            ("ttft", self.ttft.to_json()),
            ("tpot", self.tpot.to_json()),
            ("e2e", self.e2e.to_json()),
            ("ttfb", self.ttfb.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for us in [10u64, 20, 30, 50, 100, 200, 500, 1000, 5000, 100000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50:?} {p95:?} {p99:?}");
        assert!(h.max() >= p99);
    }

    #[test]
    fn histogram_bucket_accuracy_within_growth_factor() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(Duration::from_micros(1000));
        }
        let p50 = h.quantile(0.5).as_micros() as f64;
        assert!(p50 >= 1000.0 && p50 <= 1500.0 * 1.5, "p50={p50}");
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn metrics_json_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.requests_admitted);
        Metrics::add(&m.tokens_decoded, 42);
        m.ttft.record(Duration::from_millis(3));
        let j = m.to_json();
        assert_eq!(j.get("requests_admitted").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("tokens_decoded").unwrap().as_u64(), Some(42));
        assert_eq!(j.get("ttft").unwrap().get("count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn simd_dispatch_gauge_in_json() {
        let j = Metrics::new().to_json();
        let d = j.get("simd_dispatch").unwrap().as_str().unwrap();
        assert!(["scalar", "avx2", "neon"].contains(&d), "unexpected dispatch name {d:?}");
    }

    #[test]
    fn kv_cache_gauges_in_json() {
        let m = Metrics::new();
        Metrics::set(&m.kv_prefix_tokens_saved, 32);
        Metrics::add(&m.tokens_prefilled, 96);
        Metrics::set(&m.kv_swap_outs, 3);
        Metrics::set(&m.kv_blocks_used, 7);
        let j = m.to_json();
        let kv = j.get("kv_cache").unwrap();
        assert_eq!(kv.get("prefix_tokens_saved").unwrap().as_u64(), Some(32));
        assert_eq!(kv.get("swap_outs").unwrap().as_u64(), Some(3));
        assert_eq!(kv.get("blocks_used").unwrap().as_u64(), Some(7));
        let rate = kv.get("prefix_hit_rate").unwrap().as_f64().unwrap();
        assert!((rate - 0.25).abs() < 1e-9, "rate {rate}");
        // gauges overwrite rather than accumulate
        Metrics::set(&m.kv_swap_outs, 2);
        assert_eq!(m.kv_swap_outs.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn quant_gauges_in_json() {
        let m = Metrics::new();
        Metrics::set(&m.weight_bytes_f32, 4000);
        Metrics::set(&m.weight_bytes_resident, 1100);
        Metrics::set(&m.kv_quantized_blocks, 5);
        Metrics::set(&m.kv_bytes_per_token, 96);
        let j = m.to_json();
        let q = j.get("quant").unwrap();
        assert_eq!(q.get("weight_bytes_f32").unwrap().as_u64(), Some(4000));
        assert_eq!(q.get("weight_bytes_resident").unwrap().as_u64(), Some(1100));
        assert_eq!(q.get("weight_bytes_saved").unwrap().as_u64(), Some(2900));
        let kv = j.get("kv_cache").unwrap();
        assert_eq!(kv.get("quantized_blocks").unwrap().as_u64(), Some(5));
        assert_eq!(kv.get("bytes_per_token").unwrap().as_u64(), Some(96));
    }

    #[test]
    fn attn_gauges_in_json() {
        let m = Metrics::new();
        Metrics::set(&m.attn_paged_reads_bytes, 4096);
        Metrics::set(&m.attn_gather_bytes_avoided, 8192);
        let j = m.to_json();
        let a = j.get("attn").unwrap();
        assert_eq!(a.get("paged_reads_bytes").unwrap().as_u64(), Some(4096));
        assert_eq!(a.get("gather_bytes_avoided").unwrap().as_u64(), Some(8192));
        assert_eq!(a.get("gather_calls").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn shard_gauges_in_json() {
        let m = Metrics::new();
        let j = m.to_json();
        let s = j.get("shard").unwrap();
        assert_eq!(s.get("workers").unwrap().as_u64(), Some(0));
        assert_eq!(s.get("mode").unwrap().as_str(), Some("off"));
        Metrics::set(&m.shard_workers, 4);
        Metrics::set(&m.shard_mode, 1);
        Metrics::add(&m.shard_allreduce_calls, 12);
        Metrics::add(&m.shard_allreduce_bytes, 4096);
        let j = m.to_json();
        let s = j.get("shard").unwrap();
        assert_eq!(s.get("workers").unwrap().as_u64(), Some(4));
        assert_eq!(s.get("mode").unwrap().as_str(), Some("tp"));
        assert_eq!(s.get("allreduce_calls").unwrap().as_u64(), Some(12));
        assert_eq!(s.get("allreduce_bytes").unwrap().as_u64(), Some(4096));
        Metrics::set(&m.shard_mode, 2);
        Metrics::inc(&m.shard_router_prefix_hits);
        let j = m.to_json();
        let s = j.get("shard").unwrap();
        assert_eq!(s.get("mode").unwrap().as_str(), Some("dp"));
        assert_eq!(s.get("router_prefix_hits").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn alloc_gauges_in_json() {
        let m = Metrics::new();
        let j = m.to_json();
        let a = j.get("alloc").unwrap();
        assert_eq!(a.get("arena_bytes").unwrap().as_u64(), Some(0));
        assert_eq!(a.get("steady_state_allocs").unwrap().as_u64(), Some(0));
        Metrics::set(&m.alloc_arena_bytes, 1 << 20);
        Metrics::set(&m.alloc_steady_state_allocs, 3);
        let j = m.to_json();
        let a = j.get("alloc").unwrap();
        assert_eq!(a.get("arena_bytes").unwrap().as_u64(), Some(1 << 20));
        assert_eq!(a.get("steady_state_allocs").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn speculative_gauges_in_json() {
        let m = Metrics::new();
        Metrics::add(&m.spec_rounds, 10);
        Metrics::add(&m.spec_tokens_drafted, 40);
        Metrics::add(&m.spec_tokens_accepted, 30);
        Metrics::inc(&m.spec_fallbacks);
        let j = m.to_json();
        let s = j.get("speculative").unwrap();
        assert_eq!(s.get("rounds").unwrap().as_u64(), Some(10));
        assert_eq!(s.get("tokens_drafted").unwrap().as_u64(), Some(40));
        assert_eq!(s.get("tokens_accepted").unwrap().as_u64(), Some(30));
        assert_eq!(s.get("fallbacks").unwrap().as_u64(), Some(1));
        let rate = s.get("accept_rate").unwrap().as_f64().unwrap();
        assert!((rate - 0.75).abs() < 1e-9, "rate {rate}");
        // empty drafting reports 0, not NaN
        assert_eq!(Metrics::new().spec_accept_rate(), 0.0);
    }

    #[test]
    fn prefill_and_budget_gauges_in_json() {
        let m = Metrics::new();
        Metrics::add(&m.prefill_chunks, 5);
        Metrics::add(&m.prefill_chunk_tokens, 1280);
        Metrics::set(&m.budget_token_limit, 2048);
        Metrics::set(&m.budget_tokens_planned, 512);
        let j = m.to_json();
        let p = j.get("prefill").unwrap();
        assert_eq!(p.get("chunks").unwrap().as_u64(), Some(5));
        assert_eq!(p.get("chunk_tokens").unwrap().as_u64(), Some(1280));
        let b = j.get("budget").unwrap();
        assert_eq!(b.get("token_limit").unwrap().as_u64(), Some(2048));
        assert_eq!(b.get("tokens_planned").unwrap().as_u64(), Some(512));
        let u = b.get("utilization").unwrap().as_f64().unwrap();
        assert!((u - 0.25).abs() < 1e-9, "utilization {u}");
        // an idle scheduler reports 0, not NaN
        assert_eq!(Metrics::new().budget_utilization(), 0.0);
    }

    #[test]
    fn server_gauges_in_json() {
        let m = Metrics::new();
        Metrics::inc(&m.conns_accepted);
        Metrics::set(&m.conns_open, 1);
        Metrics::inc(&m.requests_shed);
        Metrics::add(&m.stream_tokens_sent, 12);
        m.write_queue_peak_bytes.fetch_max(777, Ordering::Relaxed);
        m.ttfb.record(Duration::from_millis(2));
        let j = m.to_json();
        let s = j.get("server").unwrap();
        assert_eq!(s.get("conns_accepted").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("conns_open").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("requests_shed").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("requests_rate_limited").unwrap().as_u64(), Some(0));
        assert_eq!(s.get("stream_tokens_sent").unwrap().as_u64(), Some(12));
        assert_eq!(s.get("write_queue_peak_bytes").unwrap().as_u64(), Some(777));
        assert_eq!(j.get("ttfb").unwrap().get("count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn concurrent_recording() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        Metrics::inc(&m.tokens_decoded);
                        m.tpot.record(Duration::from_micros(100));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.tokens_decoded.load(Ordering::Relaxed), 4000);
        assert_eq!(m.tpot.count(), 4000);
    }
}
