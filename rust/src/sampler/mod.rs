//! Token sampling: greedy, temperature, top-k, top-p (nucleus).
//!
//! Deterministic given a seeded [`Xoshiro256`] stream — the serving e2e
//! example replays identical requests against the vanilla and merged
//! engines and requires identical outputs, which holds because surgery is
//! function-preserving and sampling is seed-deterministic.

use crate::util::rng::Xoshiro256;

/// Sampling configuration for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplerCfg {
    /// 0 → greedy argmax.
    pub temperature: f32,
    /// 0 → disabled.
    pub top_k: usize,
    /// 1.0 → disabled.
    pub top_p: f32,
}

impl Default for SamplerCfg {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
        }
    }
}

impl SamplerCfg {
    pub fn greedy() -> Self {
        Self::default()
    }

    /// Pure argmax sampling — the regime in which the speculative
    /// [`accept_greedy`] rule makes drafted output token-identical to plain
    /// decoding. The scheduler only speculates on greedy requests.
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.temperature < 0.0 || !self.temperature.is_finite() {
            return Err(format!("temperature {} invalid", self.temperature));
        }
        if !(0.0..=1.0).contains(&self.top_p) {
            return Err(format!("top_p {} not in [0,1]", self.top_p));
        }
        Ok(())
    }
}

/// Sample one token id from a logits row.
pub fn sample(logits: &[f32], cfg: &SamplerCfg, rng: &mut Xoshiro256) -> u32 {
    debug_assert!(!logits.is_empty());
    if cfg.temperature == 0.0 {
        return argmax(logits);
    }
    // softmax with temperature over candidate set
    let inv_t = 1.0 / cfg.temperature;
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    // top-k: keep k largest
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| {
            logits[b as usize].partial_cmp(&logits[a as usize]).unwrap()
        });
        idx.truncate(cfg.top_k);
    } else if cfg.top_p < 1.0 {
        idx.sort_unstable_by(|&a, &b| {
            logits[b as usize].partial_cmp(&logits[a as usize]).unwrap()
        });
    }
    let mx = idx
        .iter()
        .map(|&i| logits[i as usize])
        .fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = idx
        .iter()
        .map(|&i| ((logits[i as usize] - mx) * inv_t).exp())
        .collect();
    let sum: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= sum;
    }
    // top-p: truncate the (sorted) tail once cumulative mass ≥ p
    if cfg.top_p < 1.0 {
        let mut cum = 0.0f32;
        let mut cut = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= cfg.top_p {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        idx.truncate(cut);
        let s: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= s;
        }
    }
    // inverse-CDF draw
    let u = rng.next_f32();
    let mut cum = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        cum += p;
        if u < cum {
            return idx[i];
        }
    }
    *idx.last().unwrap()
}

/// Greedy speculative acceptance (factored out so a stochastic
/// rejection-sampling rule can slot in beside it later).
///
/// `rows` holds the target's verify logits: one row per consumed token for
/// the input `[committed_next, drafts[0], ..., drafts[k-1]]`, so
/// `rows.len() == drafts.len() + 1` and `rows[j]` scores the position that
/// `drafts[j]` claimed. Returns `(n_accepted, next_token)`:
/// `drafts[..n_accepted]` is the longest prefix the target agrees with, and
/// `next_token` is the target's own argmax at the first disagreement — or
/// the free bonus token when every draft was accepted. Because each
/// committed token is exactly the target's argmax given the committed
/// history, the output stream is token-identical to plain greedy decoding.
pub fn accept_greedy(drafts: &[u32], rows: &[Vec<f32>]) -> (usize, u32) {
    assert_eq!(
        rows.len(),
        drafts.len() + 1,
        "verify returns one row per consumed token"
    );
    let mut a = 0;
    while a < drafts.len() && argmax(&rows[a]) == drafts[a] {
        a += 1;
    }
    (a, argmax(&rows[a]))
}

/// Argmax with lowest-index tie-break.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = [0.1, 3.0, -2.0, 2.9];
        assert_eq!(sample(&logits, &SamplerCfg::greedy(), &mut Xoshiro256::seed_from_u64(1)), 1);
    }

    #[test]
    fn greedy_tie_break_lowest_index() {
        let logits = [5.0, 5.0, 1.0];
        assert_eq!(argmax(&logits), 0);
    }

    #[test]
    fn temperature_sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..50).map(|i| (i as f32 * 0.37).sin()).collect();
        let cfg = SamplerCfg {
            temperature: 1.0,
            ..Default::default()
        };
        let mut r1 = Xoshiro256::seed_from_u64(7);
        let mut r2 = Xoshiro256::seed_from_u64(7);
        for _ in 0..20 {
            assert_eq!(sample(&logits, &cfg, &mut r1), sample(&logits, &cfg, &mut r2));
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [10.0, 9.0, 8.0, -50.0, -60.0];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 2,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..200 {
            let t = sample(&logits, &cfg, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        // one dominant token (p≈0.99) → top_p=0.5 must always pick it
        let logits = [10.0, 1.0, 0.5, 0.1];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.5,
        };
        let mut rng = Xoshiro256::seed_from_u64(4);
        for _ in 0..100 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 0);
        }
    }

    #[test]
    fn high_temperature_flattens() {
        // at T→∞ all tokens should appear
        let logits = [2.0, 1.0, 0.0, -1.0];
        let cfg = SamplerCfg {
            temperature: 100.0,
            top_k: 0,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[sample(&logits, &cfg, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "seen {seen:?}");
    }

    #[test]
    fn distribution_roughly_matches_softmax() {
        let logits = [1.0f32, 0.0];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(6);
        let n = 20_000;
        let mut c0 = 0;
        for _ in 0..n {
            if sample(&logits, &cfg, &mut rng) == 0 {
                c0 += 1;
            }
        }
        let p0 = c0 as f64 / n as f64;
        let want = (1.0f64).exp() / ((1.0f64).exp() + 1.0); // ≈ 0.731
        assert!((p0 - want).abs() < 0.02, "p0={p0} want≈{want}");
    }

    fn one_hot(vocab: usize, hot: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; vocab];
        v[hot] = 1.0;
        v
    }

    #[test]
    fn accept_greedy_full_acceptance_returns_bonus() {
        // target agrees with both drafts; bonus token from the last row
        let rows = vec![one_hot(8, 3), one_hot(8, 5), one_hot(8, 7)];
        let (a, next) = accept_greedy(&[3, 5], &rows);
        assert_eq!(a, 2);
        assert_eq!(next, 7);
    }

    #[test]
    fn accept_greedy_rejection_returns_correction() {
        // target disagrees at the second draft: accept 1, correct to 6
        let rows = vec![one_hot(8, 3), one_hot(8, 6), one_hot(8, 7)];
        let (a, next) = accept_greedy(&[3, 5], &rows);
        assert_eq!(a, 1);
        assert_eq!(next, 6);
    }

    #[test]
    fn accept_greedy_immediate_rejection() {
        let rows = vec![one_hot(8, 2), one_hot(8, 4)];
        let (a, next) = accept_greedy(&[3], &rows);
        assert_eq!(a, 0);
        assert_eq!(next, 2, "correction is the rejecting row's argmax");
    }

    #[test]
    fn accept_greedy_zero_drafts_is_plain_decode() {
        let rows = vec![one_hot(8, 4)];
        let (a, next) = accept_greedy(&[], &rows);
        assert_eq!((a, next), (0, 4));
    }

    #[test]
    fn is_greedy_tracks_temperature() {
        assert!(SamplerCfg::greedy().is_greedy());
        assert!(!SamplerCfg { temperature: 0.7, ..Default::default() }.is_greedy());
    }

    #[test]
    fn cfg_validation() {
        assert!(SamplerCfg::greedy().validate().is_ok());
        assert!(SamplerCfg {
            temperature: -1.0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.5
        }
        .validate()
        .is_err());
    }
}
