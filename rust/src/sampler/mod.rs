//! Token sampling: greedy, temperature, top-k, top-p (nucleus), the two
//! speculative acceptance rules ([`accept_greedy`], [`accept_stochastic`]),
//! and grammar-constrained masking ([`grammar`]).
//!
//! Deterministic given a seeded [`Xoshiro256`] stream — the serving e2e
//! example replays identical requests against the vanilla and merged
//! engines and requires identical outputs, which holds because surgery is
//! function-preserving and sampling is seed-deterministic.
//!
//! Every path here is total over arbitrary `f32` rows: NaN and ±∞ logits
//! never panic (they reach this code from model output, which the scheduler
//! thread must survive) — NaN sorts as −∞, +∞ takes the whole mass, and an
//! all-(−∞/NaN) row falls back to a uniform draw over the candidate set.

pub mod grammar;

use crate::util::rng::Xoshiro256;

/// Sampling configuration for one request.
///
/// Contract (enforced by [`SamplerCfg::validate`], which the server calls at
/// admission): `temperature` is finite and ≥ 0 (0 → greedy argmax);
/// `top_p` ∈ (0, 1] (1.0 → disabled — a nucleus of zero mass is degenerate,
/// not greedy, so 0.0 and non-finite values are rejected); `top_k` is
/// unconstrained (0 → disabled).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplerCfg {
    /// 0 → greedy argmax.
    pub temperature: f32,
    /// 0 → disabled.
    pub top_k: usize,
    /// 1.0 → disabled.
    pub top_p: f32,
}

impl Default for SamplerCfg {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
        }
    }
}

impl SamplerCfg {
    pub fn greedy() -> Self {
        Self::default()
    }

    /// Pure argmax sampling. The scheduler dispatches speculative
    /// acceptance on this: greedy requests use [`accept_greedy`], everything
    /// else uses [`accept_stochastic`] — both reproduce the plain decoding
    /// stream exactly.
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.temperature < 0.0 || !self.temperature.is_finite() {
            return Err(format!("temperature {} invalid (want finite, >= 0)", self.temperature));
        }
        // NaN fails the first comparison, so this single condition rejects
        // 0.0 (empty nucleus), negatives, >1, and every non-finite value.
        if !(self.top_p > 0.0 && self.top_p <= 1.0) {
            return Err(format!("top_p {} not in (0,1]", self.top_p));
        }
        Ok(())
    }
}

/// Reusable sampling scratch: the candidate id / probability tables the
/// temperature path builds per draw. Capacity is retained across draws, so
/// a scheduler holding one of these samples without heap allocation in the
/// steady state (`tests/alloc_regression.rs`).
#[derive(Debug, Default)]
pub struct SamplerScratch {
    idx: Vec<u32>,
    probs: Vec<f32>,
}

impl SamplerScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Build the candidate distribution for a temperature>0 draw into `s`
/// (`s.idx` / `s.probs` in inverse-CDF walk order).
///
/// NaN logits are mapped to −∞ up front so they order deterministically
/// (`total_cmp`, never `partial_cmp().unwrap()`) and drop out of the
/// support; a row whose candidates are all −∞ after that mapping yields a
/// uniform distribution (panic-free degenerate fallback — grammar masking
/// guarantees callers a non-empty support, this guards the guarantee); a
/// row containing +∞ puts the softmax-limit mass uniformly on the +∞
/// entries. On finite rows this is byte-for-byte the pre-hardening
/// pipeline: identical candidate order, softmax, nucleus cut, and CDF.
///
/// The comparator is a strict total order — descending value with
/// ascending-index tie-break — so the sorted sequence is *unique*. That is
/// what lets top-k run as an O(n) `select_nth_unstable_by` partition
/// followed by a sort of only the k survivors: with no comparator ties,
/// partition-then-sort provably equals the first k of a full sort (pinned
/// by `top_k_partition_matches_full_sort`).
fn dist_into(logits: &[f32], cfg: &SamplerCfg, s: &mut SamplerScratch) {
    let inv_t = 1.0 / cfg.temperature;
    let val = |i: u32| {
        let v = logits[i as usize];
        if v.is_nan() {
            f32::NEG_INFINITY
        } else {
            v
        }
    };
    let by_desc = |a: &u32, b: &u32| val(*b).total_cmp(&val(*a)).then(a.cmp(b));
    let idx = &mut s.idx;
    let probs = &mut s.probs;
    idx.clear();
    idx.extend(0..logits.len() as u32);
    // top-k: keep k largest — partition, drop the tail, order the keepers
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        idx.select_nth_unstable_by(cfg.top_k - 1, by_desc);
        idx.truncate(cfg.top_k);
        idx.sort_unstable_by(by_desc);
    } else if cfg.top_p < 1.0 {
        idx.sort_unstable_by(by_desc);
    }
    let mx = idx.iter().map(|&i| val(i)).fold(f32::NEG_INFINITY, f32::max);
    probs.clear();
    if mx == f32::INFINITY {
        probs.extend(
            idx.iter()
                .map(|&i| if val(i) == f32::INFINITY { 1.0 } else { 0.0 }),
        );
    } else if mx == f32::NEG_INFINITY {
        probs.extend(idx.iter().map(|_| 1.0f32));
    } else {
        // (val − mx) ≤ 0, so exp never overflows and the max entry
        // contributes exp(0)=1 — the normalizing sum is always ≥ 1.
        probs.extend(idx.iter().map(|&i| ((val(i) - mx) * inv_t).exp()));
    }
    let sum: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= sum;
    }
    // top-p: truncate the (sorted) tail once cumulative mass ≥ p
    if cfg.top_p < 1.0 {
        let mut cum = 0.0f32;
        let mut cut = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= cfg.top_p {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        idx.truncate(cut);
        let s: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= s;
        }
    }
}

/// Sample one token id from a logits row. Consumes exactly one `next_f32`
/// from `rng` when `temperature > 0`, none when greedy — the scheduler's
/// RNG stream discipline (see [`accept_stochastic`]) leans on this.
///
/// Thin wrapper over [`sample_with`] with fresh scratch; callers on the
/// decode hot path should hold a [`SamplerScratch`] and call `sample_with`.
pub fn sample(logits: &[f32], cfg: &SamplerCfg, rng: &mut Xoshiro256) -> u32 {
    let mut scratch = SamplerScratch::new();
    sample_with(logits, cfg, rng, &mut scratch)
}

/// [`sample`] with caller-owned scratch: identical draw (same candidate
/// order, same single `next_f32`), zero heap allocations once the scratch
/// has warmed to the row's vocab size.
pub fn sample_with(
    logits: &[f32],
    cfg: &SamplerCfg,
    rng: &mut Xoshiro256,
    scratch: &mut SamplerScratch,
) -> u32 {
    debug_assert!(!logits.is_empty());
    if cfg.temperature == 0.0 {
        return argmax(logits);
    }
    dist_into(logits, cfg, scratch);
    // inverse-CDF draw
    let u = rng.next_f32();
    let mut cum = 0.0f32;
    for (i, &p) in scratch.probs.iter().enumerate() {
        cum += p;
        if u < cum {
            return scratch.idx[i];
        }
    }
    *scratch.idx.last().unwrap()
}

/// Greedy speculative acceptance.
///
/// `rows` holds the target's verify logits: one row per consumed token for
/// the input `[committed_next, drafts[0], ..., drafts[k-1]]`, so
/// `rows.len() == drafts.len() + 1` and `rows[j]` scores the position that
/// `drafts[j]` claimed. Returns `(n_accepted, next_token)`:
/// `drafts[..n_accepted]` is the longest prefix the target agrees with, and
/// `next_token` is the target's own argmax at the first disagreement — or
/// the free bonus token when every draft was accepted. Because each
/// committed token is exactly the target's argmax given the committed
/// history, the output stream is token-identical to plain greedy decoding.
pub fn accept_greedy(drafts: &[u32], rows: &[Vec<f32>]) -> (usize, u32) {
    assert_eq!(
        rows.len(),
        drafts.len() + 1,
        "verify returns one row per consumed token"
    );
    let mut a = 0;
    while a < drafts.len() && argmax(&rows[a]) == drafts[a] {
        a += 1;
    }
    (a, argmax(&rows[a]))
}

/// Stochastic speculative acceptance: the standard rejection rule,
/// specialized to this scheduler's argmax (point-mass) draft proposals.
///
/// The textbook rule accepts draft token `x` with probability
/// `min(1, p_target(x) / p_draft(x))` and, on rejection, resamples from the
/// normalized residual `max(0, p_target − p_draft)`. Our draft proposes its
/// argmax, i.e. `p_draft` is the point mass `δ_x`; for a point mass the
/// rule reduces *exactly* to: draw `y ~ p_target` at the position — the
/// same candidate set, nucleus cut, and inverse-CDF walk plain decoding
/// uses — and accept iff `y == x`. (Acceptance probability is `p_target(x)`
/// = `min(1, p_target(x)/1)`; conditioned on `y ≠ x`, `y` is distributed as
/// the normalized residual of `p_target` minus the point mass.) So the
/// correction token on rejection is `y` itself, and the bonus token after
/// full acceptance is one more plain draw from the last row.
///
/// **RNG stream discipline** — the invariant golden conformance and the
/// scheduler fuzzer pin: each committed token consumes exactly one
/// `next_f32` from the request's `Xoshiro256` stream, in commit order, and
/// the verify `rows` are bit-identical to sequential decode rows
/// ([`crate::coordinator::engine::Engine::verify_batch`]). The draw plain
/// decoding would make at a position is therefore the very draw made here,
/// and **stochastic speculative output is byte-identical to plain
/// stochastic output for a fixed seed** — not merely equal in
/// distribution. Draws consumed for rows past an EOS / length / grammar
/// cut are unobservable: the request finishes and its stream is dropped.
/// Drafting itself consumes no request randomness (the draft is argmax-
/// only), so the capacity-failure fallback to plain decoding leaves the
/// stream untouched.
///
/// Same `rows` shape and return convention as [`accept_greedy`].
pub fn accept_stochastic(
    drafts: &[u32],
    rows: &[Vec<f32>],
    cfg: &SamplerCfg,
    rng: &mut Xoshiro256,
) -> (usize, u32) {
    let mut scratch = SamplerScratch::new();
    accept_stochastic_with(drafts, rows, cfg, rng, &mut scratch)
}

/// [`accept_stochastic`] with caller-owned sampling scratch — same draws,
/// same stream discipline, no per-call heap allocation.
pub fn accept_stochastic_with(
    drafts: &[u32],
    rows: &[Vec<f32>],
    cfg: &SamplerCfg,
    rng: &mut Xoshiro256,
    scratch: &mut SamplerScratch,
) -> (usize, u32) {
    assert_eq!(
        rows.len(),
        drafts.len() + 1,
        "verify returns one row per consumed token"
    );
    debug_assert!(!cfg.is_greedy(), "greedy requests use accept_greedy");
    for (j, &d) in drafts.iter().enumerate() {
        let y = sample_with(&rows[j], cfg, rng, scratch);
        if y != d {
            return (j, y);
        }
    }
    (
        drafts.len(),
        sample_with(&rows[drafts.len()], cfg, rng, scratch),
    )
}

/// Argmax with lowest-index tie-break. NaN entries are skipped (a row of
/// only NaN yields token 0) — on finite rows this matches the naive fold.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = [0.1, 3.0, -2.0, 2.9];
        assert_eq!(sample(&logits, &SamplerCfg::greedy(), &mut Xoshiro256::seed_from_u64(1)), 1);
    }

    #[test]
    fn greedy_tie_break_lowest_index() {
        let logits = [5.0, 5.0, 1.0];
        assert_eq!(argmax(&logits), 0);
    }

    #[test]
    fn temperature_sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..50).map(|i| (i as f32 * 0.37).sin()).collect();
        let cfg = SamplerCfg {
            temperature: 1.0,
            ..Default::default()
        };
        let mut r1 = Xoshiro256::seed_from_u64(7);
        let mut r2 = Xoshiro256::seed_from_u64(7);
        for _ in 0..20 {
            assert_eq!(sample(&logits, &cfg, &mut r1), sample(&logits, &cfg, &mut r2));
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [10.0, 9.0, 8.0, -50.0, -60.0];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 2,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..200 {
            let t = sample(&logits, &cfg, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        // one dominant token (p≈0.99) → top_p=0.5 must always pick it
        let logits = [10.0, 1.0, 0.5, 0.1];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.5,
        };
        let mut rng = Xoshiro256::seed_from_u64(4);
        for _ in 0..100 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 0);
        }
    }

    #[test]
    fn high_temperature_flattens() {
        // at T→∞ all tokens should appear
        let logits = [2.0, 1.0, 0.0, -1.0];
        let cfg = SamplerCfg {
            temperature: 100.0,
            top_k: 0,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[sample(&logits, &cfg, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "seen {seen:?}");
    }

    #[test]
    fn distribution_roughly_matches_softmax() {
        let logits = [1.0f32, 0.0];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(6);
        let n = 20_000;
        let mut c0 = 0;
        for _ in 0..n {
            if sample(&logits, &cfg, &mut rng) == 0 {
                c0 += 1;
            }
        }
        let p0 = c0 as f64 / n as f64;
        let want = (1.0f64).exp() / ((1.0f64).exp() + 1.0); // ≈ 0.731
        assert!((p0 - want).abs() < 0.02, "p0={p0} want≈{want}");
    }

    /// The PR-8 regression: a single NaN logit used to panic the scheduler
    /// thread via `partial_cmp().unwrap()` in the top-k/top-p sorts. Feed
    /// NaN, +∞, and all-−∞ rows through every cfg combination and require
    /// (a) no panic and (b) an in-support token wherever support exists.
    #[test]
    fn non_finite_logits_never_panic_and_stay_in_support() {
        let rows: Vec<Vec<f32>> = vec![
            vec![0.5, f32::NAN, 2.0, 1.0],               // NaN mid-row
            vec![f32::NAN, f32::NAN, 3.0, 1.0],          // NaN prefix
            vec![0.0, f32::INFINITY, 1.0, f32::NAN],     // +∞ wins, NaN too
            vec![f32::NEG_INFINITY; 4],                  // empty support
            vec![f32::NAN; 4],                           // empty support
            vec![f32::NEG_INFINITY, f32::NAN, f32::NEG_INFINITY, 7.0], // one survivor
        ];
        let cfgs: Vec<SamplerCfg> = [0.0f32, 0.7, 2.0]
            .iter()
            .flat_map(|&temperature| {
                [0usize, 2].iter().flat_map(move |&top_k| {
                    [1.0f32, 0.5].iter().map(move |&top_p| SamplerCfg {
                        temperature,
                        top_k,
                        top_p,
                    })
                })
            })
            .collect();
        let mut rng = Xoshiro256::seed_from_u64(99);
        for cfg in &cfgs {
            for row in &rows {
                for _ in 0..50 {
                    let t = sample(row, cfg, &mut rng) as usize;
                    assert!(t < row.len(), "token {t} out of range for {cfg:?}");
                    let has_support = row.iter().any(|v| !v.is_nan() && *v > f32::NEG_INFINITY);
                    if has_support && cfg.temperature > 0.0 {
                        assert!(
                            !row[t].is_nan() && row[t] > f32::NEG_INFINITY,
                            "sampled masked-out token {t} from {row:?} with {cfg:?}"
                        );
                    }
                    if cfg.temperature == 0.0 && has_support {
                        assert!(!row[t].is_nan(), "greedy picked NaN at {t} in {row:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn plus_infinity_takes_all_mass() {
        let row = [0.0, f32::INFINITY, 5.0, f32::INFINITY];
        let cfg = SamplerCfg {
            temperature: 1.0,
            ..Default::default()
        };
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..100 {
            let t = sample(&row, &cfg, &mut rng);
            assert!(t == 1 || t == 3, "finite token {t} drawn despite +inf mass");
        }
    }

    #[test]
    fn nan_hardening_preserves_finite_row_streams() {
        // total_cmp + the val() mapping must not change what finite rows
        // sample — replay a long stream against the reference pipeline
        // (plain softmax inverse-CDF with no truncation).
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.61).cos() * 3.0).collect();
        let cfg = SamplerCfg {
            temperature: 0.9,
            top_k: 0,
            top_p: 1.0,
        };
        let mut r1 = Xoshiro256::seed_from_u64(21);
        let mut r2 = Xoshiro256::seed_from_u64(21);
        for _ in 0..200 {
            let got = sample(&logits, &cfg, &mut r1);
            let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let probs: Vec<f32> = logits.iter().map(|&v| ((v - mx) / 0.9).exp()).collect();
            let sum: f32 = probs.iter().sum();
            let u = r2.next_f32();
            let mut cum = 0.0;
            let mut want = logits.len() as u32 - 1;
            for (i, &p) in probs.iter().enumerate() {
                cum += p / sum;
                if u < cum {
                    want = i as u32;
                    break;
                }
            }
            assert_eq!(got, want);
        }
    }

    fn one_hot(vocab: usize, hot: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; vocab];
        v[hot] = 1.0;
        v
    }

    #[test]
    fn accept_greedy_full_acceptance_returns_bonus() {
        // target agrees with both drafts; bonus token from the last row
        let rows = vec![one_hot(8, 3), one_hot(8, 5), one_hot(8, 7)];
        let (a, next) = accept_greedy(&[3, 5], &rows);
        assert_eq!(a, 2);
        assert_eq!(next, 7);
    }

    #[test]
    fn accept_greedy_rejection_returns_correction() {
        // target disagrees at the second draft: accept 1, correct to 6
        let rows = vec![one_hot(8, 3), one_hot(8, 6), one_hot(8, 7)];
        let (a, next) = accept_greedy(&[3, 5], &rows);
        assert_eq!(a, 1);
        assert_eq!(next, 6);
    }

    #[test]
    fn accept_greedy_immediate_rejection() {
        let rows = vec![one_hot(8, 2), one_hot(8, 4)];
        let (a, next) = accept_greedy(&[3], &rows);
        assert_eq!(a, 0);
        assert_eq!(next, 2, "correction is the rejecting row's argmax");
    }

    #[test]
    fn accept_greedy_zero_drafts_is_plain_decode() {
        let rows = vec![one_hot(8, 4)];
        let (a, next) = accept_greedy(&[], &rows);
        assert_eq!((a, next), (0, 4));
    }

    /// Rows the plain path would decode, one per position.
    fn spec_rows() -> Vec<Vec<f32>> {
        (0..5)
            .map(|j| (0..16).map(|i| ((i * 7 + j * 3) as f32 * 0.43).sin() * 2.0).collect())
            .collect()
    }

    /// The point-mass coupling made concrete: whatever the drafts are, the
    /// accepted prefix + correction must equal the draws plain decoding
    /// makes from the same rows with the same stream.
    #[test]
    fn accept_stochastic_matches_plain_draws_exactly() {
        let rows = spec_rows();
        let cfg = SamplerCfg {
            temperature: 0.8,
            top_k: 6,
            top_p: 0.95,
        };
        for seed in 0..50u64 {
            // plain decode: sample each row in order
            let mut rp = Xoshiro256::seed_from_u64(seed);
            let plain: Vec<u32> = rows.iter().map(|r| sample(r, &cfg, &mut rp)).collect();
            // adversarial drafts: agree with plain for a seed-dependent
            // prefix, then diverge
            let k = rows.len() - 1;
            let mut drafts: Vec<u32> = plain[..k].to_vec();
            let cut = (seed as usize) % (k + 1);
            for d in drafts.iter_mut().skip(cut) {
                *d = (*d + 1) % 16;
            }
            let mut rs = Xoshiro256::seed_from_u64(seed);
            let (a, next) = accept_stochastic(&drafts, &rows, &cfg, &mut rs);
            // first mismatch between plain draws and drafts decides a
            let want_a = (0..k).find(|&j| plain[j] != drafts[j]).unwrap_or(k);
            assert_eq!(a, want_a, "seed {seed}");
            assert_eq!(next, plain[want_a], "seed {seed}: correction/bonus must be the plain draw");
        }
    }

    #[test]
    fn accept_stochastic_full_acceptance_consumes_bonus_draw() {
        let rows = spec_rows();
        let cfg = SamplerCfg {
            temperature: 1.1,
            ..Default::default()
        };
        let mut rp = Xoshiro256::seed_from_u64(404);
        let plain: Vec<u32> = rows.iter().map(|r| sample(r, &cfg, &mut rp)).collect();
        let drafts = plain[..rows.len() - 1].to_vec();
        let mut rs = Xoshiro256::seed_from_u64(404);
        let (a, next) = accept_stochastic(&drafts, &rows, &cfg, &mut rs);
        assert_eq!(a, drafts.len());
        assert_eq!(next, plain[drafts.len()]);
        // both streams consumed the same number of uniforms
        assert_eq!(rp.next_u64(), rs.next_u64());
    }

    /// Full-sort oracle for the candidate pipeline: the pre-partition
    /// implementation (sort the whole vocab descending, truncate to k),
    /// sharing the exact comparator. `dist_into` must reproduce its output
    /// bit-for-bit.
    fn dist_oracle(logits: &[f32], cfg: &SamplerCfg) -> (Vec<u32>, Vec<f32>) {
        let inv_t = 1.0 / cfg.temperature;
        let val = |i: u32| {
            let v = logits[i as usize];
            if v.is_nan() {
                f32::NEG_INFINITY
            } else {
                v
            }
        };
        let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
        if cfg.top_k > 0 && cfg.top_k < logits.len() {
            idx.sort_unstable_by(|&a, &b| val(b).total_cmp(&val(a)).then(a.cmp(&b)));
            idx.truncate(cfg.top_k);
        } else if cfg.top_p < 1.0 {
            idx.sort_unstable_by(|&a, &b| val(b).total_cmp(&val(a)).then(a.cmp(&b)));
        }
        let mx = idx.iter().map(|&i| val(i)).fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f32> = if mx == f32::INFINITY {
            idx.iter()
                .map(|&i| if val(i) == f32::INFINITY { 1.0 } else { 0.0 })
                .collect()
        } else if mx == f32::NEG_INFINITY {
            vec![1.0; idx.len()]
        } else {
            idx.iter().map(|&i| ((val(i) - mx) * inv_t).exp()).collect()
        };
        let sum: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        if cfg.top_p < 1.0 {
            let mut cum = 0.0f32;
            let mut cut = probs.len();
            for (i, &p) in probs.iter().enumerate() {
                cum += p;
                if cum >= cfg.top_p {
                    cut = i + 1;
                    break;
                }
            }
            probs.truncate(cut);
            idx.truncate(cut);
            let s: f32 = probs.iter().sum();
            for p in probs.iter_mut() {
                *p /= s;
            }
        }
        (idx, probs)
    }

    /// Property test for the O(n) top-k partition: across adversarial rows
    /// (duplicate values for tie-break coverage, NaN, ±∞) and the full cfg
    /// grid, the partitioned pipeline must match the full-sort oracle
    /// bit-for-bit — ids equal, probabilities equal as bits. Scratch is
    /// deliberately reused dirty across cases: stale contents must not leak.
    #[test]
    fn top_k_partition_matches_full_sort() {
        let mut rng = Xoshiro256::seed_from_u64(2024);
        let mut scratch = SamplerScratch::new();
        for case in 0..200 {
            let n = 2 + (rng.next_u64() % 48) as usize;
            let mut row: Vec<f32> = (0..n)
                // coarse quantization forces plenty of exact ties
                .map(|_| ((rng.next_u64() % 7) as f32) - 3.0)
                .collect();
            if case % 3 == 0 {
                row[(rng.next_u64() as usize) % n] = f32::NAN;
            }
            if case % 5 == 0 {
                row[(rng.next_u64() as usize) % n] = f32::INFINITY;
            }
            if case % 7 == 0 {
                row[(rng.next_u64() as usize) % n] = f32::NEG_INFINITY;
            }
            for &top_k in &[0usize, 1, 2, n / 2, n - 1, n, n + 3] {
                for &top_p in &[1.0f32, 0.9, 0.5] {
                    let cfg = SamplerCfg {
                        temperature: 0.8,
                        top_k,
                        top_p,
                    };
                    let (want_idx, want_probs) = dist_oracle(&row, &cfg);
                    dist_into(&row, &cfg, &mut scratch);
                    assert_eq!(scratch.idx, want_idx, "case {case} k={top_k} p={top_p}");
                    let got_bits: Vec<u32> =
                        scratch.probs.iter().map(|p| p.to_bits()).collect();
                    let want_bits: Vec<u32> = want_probs.iter().map(|p| p.to_bits()).collect();
                    assert_eq!(got_bits, want_bits, "case {case} k={top_k} p={top_p}");
                }
            }
        }
    }

    /// `sample_with` over a dirty, reused scratch must replay the exact
    /// stream `sample` (fresh scratch every call) produces.
    #[test]
    fn sample_with_reused_scratch_matches_sample() {
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.61).cos() * 3.0).collect();
        let cfg = SamplerCfg {
            temperature: 0.9,
            top_k: 10,
            top_p: 0.95,
        };
        let mut r1 = Xoshiro256::seed_from_u64(33);
        let mut r2 = Xoshiro256::seed_from_u64(33);
        let mut scratch = SamplerScratch::new();
        for _ in 0..200 {
            assert_eq!(
                sample(&logits, &cfg, &mut r1),
                sample_with(&logits, &cfg, &mut r2, &mut scratch)
            );
        }
    }

    #[test]
    fn is_greedy_tracks_temperature() {
        assert!(SamplerCfg::greedy().is_greedy());
        assert!(!SamplerCfg { temperature: 0.7, ..Default::default() }.is_greedy());
    }

    #[test]
    fn cfg_validation() {
        assert!(SamplerCfg::greedy().validate().is_ok());
        assert!(SamplerCfg { temperature: 1.0, top_k: 0, top_p: 0.3 }.validate().is_ok());
        for bad in [
            SamplerCfg { temperature: -1.0, ..Default::default() },
            SamplerCfg { temperature: f32::NAN, ..Default::default() },
            SamplerCfg { temperature: f32::INFINITY, ..Default::default() },
            SamplerCfg { temperature: 1.0, top_k: 0, top_p: 1.5 },
            SamplerCfg { temperature: 1.0, top_k: 0, top_p: 0.0 },
            SamplerCfg { temperature: 1.0, top_k: 0, top_p: -0.1 },
            SamplerCfg { temperature: 1.0, top_k: 0, top_p: f32::NAN },
            SamplerCfg { temperature: 1.0, top_k: 0, top_p: f32::INFINITY },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
    }
}
