//! Token sampling: greedy, temperature, top-k, top-p (nucleus).
//!
//! Deterministic given a seeded [`Xoshiro256`] stream — the serving e2e
//! example replays identical requests against the vanilla and merged
//! engines and requires identical outputs, which holds because surgery is
//! function-preserving and sampling is seed-deterministic.

use crate::util::rng::Xoshiro256;

/// Sampling configuration for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplerCfg {
    /// 0 → greedy argmax.
    pub temperature: f32,
    /// 0 → disabled.
    pub top_k: usize,
    /// 1.0 → disabled.
    pub top_p: f32,
}

impl Default for SamplerCfg {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
        }
    }
}

impl SamplerCfg {
    pub fn greedy() -> Self {
        Self::default()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.temperature < 0.0 || !self.temperature.is_finite() {
            return Err(format!("temperature {} invalid", self.temperature));
        }
        if !(0.0..=1.0).contains(&self.top_p) {
            return Err(format!("top_p {} not in [0,1]", self.top_p));
        }
        Ok(())
    }
}

/// Sample one token id from a logits row.
pub fn sample(logits: &[f32], cfg: &SamplerCfg, rng: &mut Xoshiro256) -> u32 {
    debug_assert!(!logits.is_empty());
    if cfg.temperature == 0.0 {
        return argmax(logits);
    }
    // softmax with temperature over candidate set
    let inv_t = 1.0 / cfg.temperature;
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    // top-k: keep k largest
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| {
            logits[b as usize].partial_cmp(&logits[a as usize]).unwrap()
        });
        idx.truncate(cfg.top_k);
    } else if cfg.top_p < 1.0 {
        idx.sort_unstable_by(|&a, &b| {
            logits[b as usize].partial_cmp(&logits[a as usize]).unwrap()
        });
    }
    let mx = idx
        .iter()
        .map(|&i| logits[i as usize])
        .fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = idx
        .iter()
        .map(|&i| ((logits[i as usize] - mx) * inv_t).exp())
        .collect();
    let sum: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= sum;
    }
    // top-p: truncate the (sorted) tail once cumulative mass ≥ p
    if cfg.top_p < 1.0 {
        let mut cum = 0.0f32;
        let mut cut = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= cfg.top_p {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        idx.truncate(cut);
        let s: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= s;
        }
    }
    // inverse-CDF draw
    let u = rng.next_f32();
    let mut cum = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        cum += p;
        if u < cum {
            return idx[i];
        }
    }
    *idx.last().unwrap()
}

/// Argmax with lowest-index tie-break.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = [0.1, 3.0, -2.0, 2.9];
        assert_eq!(sample(&logits, &SamplerCfg::greedy(), &mut Xoshiro256::seed_from_u64(1)), 1);
    }

    #[test]
    fn greedy_tie_break_lowest_index() {
        let logits = [5.0, 5.0, 1.0];
        assert_eq!(argmax(&logits), 0);
    }

    #[test]
    fn temperature_sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..50).map(|i| (i as f32 * 0.37).sin()).collect();
        let cfg = SamplerCfg {
            temperature: 1.0,
            ..Default::default()
        };
        let mut r1 = Xoshiro256::seed_from_u64(7);
        let mut r2 = Xoshiro256::seed_from_u64(7);
        for _ in 0..20 {
            assert_eq!(sample(&logits, &cfg, &mut r1), sample(&logits, &cfg, &mut r2));
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [10.0, 9.0, 8.0, -50.0, -60.0];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 2,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..200 {
            let t = sample(&logits, &cfg, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        // one dominant token (p≈0.99) → top_p=0.5 must always pick it
        let logits = [10.0, 1.0, 0.5, 0.1];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.5,
        };
        let mut rng = Xoshiro256::seed_from_u64(4);
        for _ in 0..100 {
            assert_eq!(sample(&logits, &cfg, &mut rng), 0);
        }
    }

    #[test]
    fn high_temperature_flattens() {
        // at T→∞ all tokens should appear
        let logits = [2.0, 1.0, 0.0, -1.0];
        let cfg = SamplerCfg {
            temperature: 100.0,
            top_k: 0,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[sample(&logits, &cfg, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "seen {seen:?}");
    }

    #[test]
    fn distribution_roughly_matches_softmax() {
        let logits = [1.0f32, 0.0];
        let cfg = SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
        };
        let mut rng = Xoshiro256::seed_from_u64(6);
        let n = 20_000;
        let mut c0 = 0;
        for _ in 0..n {
            if sample(&logits, &cfg, &mut rng) == 0 {
                c0 += 1;
            }
        }
        let p0 = c0 as f64 / n as f64;
        let want = (1.0f64).exp() / ((1.0f64).exp() + 1.0); // ≈ 0.731
        assert!((p0 - want).abs() < 0.02, "p0={p0} want≈{want}");
    }

    #[test]
    fn cfg_validation() {
        assert!(SamplerCfg::greedy().validate().is_ok());
        assert!(SamplerCfg {
            temperature: -1.0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(SamplerCfg {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.5
        }
        .validate()
        .is_err());
    }
}
