//! Grammar-constrained decoding: an incremental JSON recognizer compiled
//! into a per-step token mask.
//!
//! The serving vocabulary is byte-level (token ids 0..=255 are raw bytes,
//! matching the tokenizer id space), so the grammar runs as a byte-wise
//! pushdown machine: a mode for the current syntactic position plus a
//! stack of open containers. `mask_row` marks every token whose byte
//! expansion keeps the machine alive as allowed (logit passed through) and
//! everything else as −∞; sampling then proceeds unchanged, so constrained
//! decoding composes with greedy, stochastic, and speculative paths
//! without touching the acceptance rules.
//!
//! **Budget-aware masking** is the completion guarantee: a token is only
//! allowed if, after consuming it, the *minimal* number of further tokens
//! needed to reach a complete document ([`min_to_complete`], exact for the
//! single-byte vocab) still fits in the request's remaining
//! `max_new_tokens`. Since the first byte of a minimal completion is
//! itself always an allowed token, the mask is non-empty at every step by
//! induction, and a constrained request always finishes by *grammar
//! completion* (reported as EOS) rather than mid-value truncation —
//! "constrained output always parses" holds unconditionally in every
//! scheduling mode. The scheduler enforces the induction base at
//! admission: `max_new_tokens ≥ 2` (the shortest document, `{}`) and a
//! vocab covering the structural ASCII range.
//!
//! The recognized language is a conservative subset of RFC 8259 (what the
//! repo's [`crate::util::json::Json::parse`] accepts): the top-level value
//! is an object or array; strings take raw bytes `0x20..=0xFF` (minus `"`
//! and `\`) and the simple escapes `\" \\ \/ \b \f \n \r \t` — `\uXXXX`
//! escapes are *not generated* (a lone surrogate would be well-formed for
//! the grammar yet unparseable, so they are excluded from the output
//! language); numbers are strict RFC numbers (no leading zeros).
//!
//! [`min_to_complete`]: JsonMachine::min_to_complete

/// Which grammar a request is constrained to. Carried on
/// [`crate::coordinator::scheduler::Request`] and the wire protocol
/// (`"constrain":"json"`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Constraint {
    Json,
}

impl Constraint {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "json" => Some(Self::Json),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Json => "json",
        }
    }
}

/// Open container kind on the machine's stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ctx {
    Obj,
    Arr,
}

/// Escape progress inside a string.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Esc {
    /// Plain string bytes.
    None,
    /// Just consumed `\`, expecting one simple escape byte.
    Slash,
}

/// Number recognizer sub-state. Terminal states (a delimiter may end the
/// number here): `Zero`, `Int`, `Frac`, `ExpDigits`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NumSt {
    Minus,
    Zero,
    Int,
    Dot,
    Frac,
    Exp,
    ExpSign,
    ExpDigits,
}

/// Syntactic position between bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Mode {
    /// Before the top-level `{` or `[`.
    Start,
    /// Top-level container closed; nothing further is accepted.
    Done,
    /// Just after `{`: first key or immediate `}`.
    ObjFirst,
    /// After `,` in an object: a key must follow.
    ObjKey,
    /// After a key string: `:` must follow.
    ObjColon,
    /// After `:`: a member value must follow.
    ObjValue,
    /// After a member value: `,` or `}`.
    ObjNext,
    /// Just after `[`: first element or immediate `]`.
    ArrFirst,
    /// After `,` in an array: an element must follow.
    ArrValue,
    /// After an element: `,` or `]`.
    ArrNext,
    /// Inside a string; `key` strings return to `ObjColon` on close.
    Str { key: bool, esc: Esc },
    Num(NumSt),
    /// Inside `true` / `false` / `null`, `pos` bytes consumed.
    Lit { word: &'static [u8], pos: usize },
}

/// The incremental JSON recognizer. `Clone` is cheap enough for per-token
/// mask probes (the stack is the only allocation).
#[derive(Clone, Debug)]
struct JsonMachine {
    stack: Vec<Ctx>,
    mode: Mode,
    dead: bool,
}

fn is_ws(b: u8) -> bool {
    matches!(b, b' ' | b'\t' | b'\n' | b'\r')
}

impl JsonMachine {
    fn new() -> Self {
        Self {
            stack: Vec::new(),
            mode: Mode::Start,
            dead: false,
        }
    }

    fn kill(&mut self) {
        self.dead = true;
    }

    /// Consume one byte; `false` → the byte is not a valid continuation
    /// and the machine is dead from here on.
    fn accept_byte(&mut self, b: u8) -> bool {
        if self.dead || !self.step(b) {
            self.dead = true;
            return false;
        }
        true
    }

    /// A value just finished: return to the enclosing container's
    /// between-values position (top-level values are containers only, so
    /// the stack decides unambiguously).
    fn end_value(&mut self) {
        self.mode = match self.stack.last() {
            Some(Ctx::Obj) => Mode::ObjNext,
            Some(Ctx::Arr) => Mode::ArrNext,
            None => Mode::Done,
        };
    }

    /// Dispatch a value-start byte (valid in ObjValue / ArrValue /
    /// ArrFirst / Start-restricted positions).
    fn start_value(&mut self, b: u8, containers_only: bool) -> bool {
        match b {
            b'{' => {
                self.stack.push(Ctx::Obj);
                self.mode = Mode::ObjFirst;
                true
            }
            b'[' => {
                self.stack.push(Ctx::Arr);
                self.mode = Mode::ArrFirst;
                true
            }
            _ if containers_only => false,
            b'"' => {
                self.mode = Mode::Str { key: false, esc: Esc::None };
                true
            }
            b'-' => {
                self.mode = Mode::Num(NumSt::Minus);
                true
            }
            b'0' => {
                self.mode = Mode::Num(NumSt::Zero);
                true
            }
            b'1'..=b'9' => {
                self.mode = Mode::Num(NumSt::Int);
                true
            }
            b't' => {
                self.mode = Mode::Lit { word: b"true", pos: 1 };
                true
            }
            b'f' => {
                self.mode = Mode::Lit { word: b"false", pos: 1 };
                true
            }
            b'n' => {
                self.mode = Mode::Lit { word: b"null", pos: 1 };
                true
            }
            _ => false,
        }
    }

    fn step(&mut self, b: u8) -> bool {
        match self.mode.clone() {
            Mode::Start => is_ws(b) || self.start_value(b, true),
            Mode::Done => false,
            Mode::ObjFirst => {
                if is_ws(b) {
                    return true;
                }
                match b {
                    b'"' => {
                        self.mode = Mode::Str { key: true, esc: Esc::None };
                        true
                    }
                    b'}' => {
                        self.stack.pop();
                        self.end_value();
                        true
                    }
                    _ => false,
                }
            }
            Mode::ObjKey => {
                if is_ws(b) {
                    return true;
                }
                if b == b'"' {
                    self.mode = Mode::Str { key: true, esc: Esc::None };
                    true
                } else {
                    false
                }
            }
            Mode::ObjColon => {
                if is_ws(b) {
                    return true;
                }
                if b == b':' {
                    self.mode = Mode::ObjValue;
                    true
                } else {
                    false
                }
            }
            Mode::ObjValue => is_ws(b) || self.start_value(b, false),
            Mode::ObjNext => {
                if is_ws(b) {
                    return true;
                }
                match b {
                    b',' => {
                        self.mode = Mode::ObjKey;
                        true
                    }
                    b'}' => {
                        self.stack.pop();
                        self.end_value();
                        true
                    }
                    _ => false,
                }
            }
            Mode::ArrFirst => {
                if is_ws(b) {
                    return true;
                }
                if b == b']' {
                    self.stack.pop();
                    self.end_value();
                    true
                } else {
                    self.start_value(b, false)
                }
            }
            Mode::ArrValue => is_ws(b) || self.start_value(b, false),
            Mode::ArrNext => {
                if is_ws(b) {
                    return true;
                }
                match b {
                    b',' => {
                        self.mode = Mode::ArrValue;
                        true
                    }
                    b']' => {
                        self.stack.pop();
                        self.end_value();
                        true
                    }
                    _ => false,
                }
            }
            Mode::Str { key, esc } => match esc {
                Esc::Slash => match b {
                    b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => {
                        self.mode = Mode::Str { key, esc: Esc::None };
                        true
                    }
                    // no \uXXXX: lone surrogates are grammar-valid but
                    // unparseable, so the escape is excluded outright
                    _ => false,
                },
                Esc::None => match b {
                    b'"' => {
                        if key {
                            self.mode = Mode::ObjColon;
                        } else {
                            self.end_value();
                        }
                        true
                    }
                    b'\\' => {
                        self.mode = Mode::Str { key, esc: Esc::Slash };
                        true
                    }
                    // control bytes must be escaped
                    0x00..=0x1f => false,
                    _ => true,
                },
            },
            Mode::Num(st) => {
                let next = match (st, b) {
                    (NumSt::Minus, b'0') => Some(NumSt::Zero),
                    (NumSt::Minus, b'1'..=b'9') => Some(NumSt::Int),
                    (NumSt::Zero, b'.') => Some(NumSt::Dot),
                    (NumSt::Zero, b'e' | b'E') => Some(NumSt::Exp),
                    (NumSt::Int, b'0'..=b'9') => Some(NumSt::Int),
                    (NumSt::Int, b'.') => Some(NumSt::Dot),
                    (NumSt::Int, b'e' | b'E') => Some(NumSt::Exp),
                    (NumSt::Dot, b'0'..=b'9') => Some(NumSt::Frac),
                    (NumSt::Frac, b'0'..=b'9') => Some(NumSt::Frac),
                    (NumSt::Frac, b'e' | b'E') => Some(NumSt::Exp),
                    (NumSt::Exp, b'+' | b'-') => Some(NumSt::ExpSign),
                    (NumSt::Exp | NumSt::ExpSign, b'0'..=b'9') => Some(NumSt::ExpDigits),
                    (NumSt::ExpDigits, b'0'..=b'9') => Some(NumSt::ExpDigits),
                    _ => None,
                };
                if let Some(n) = next {
                    self.mode = Mode::Num(n);
                    return true;
                }
                // a terminal number state ends at the delimiter, which is
                // then re-dispatched through the enclosing position
                if matches!(st, NumSt::Zero | NumSt::Int | NumSt::Frac | NumSt::ExpDigits) {
                    self.end_value();
                    self.step(b)
                } else {
                    false
                }
            }
            Mode::Lit { word, pos } => {
                if b == word[pos] {
                    if pos + 1 == word.len() {
                        self.end_value();
                    } else {
                        self.mode = Mode::Lit { word, pos: pos + 1 };
                    }
                    true
                } else {
                    false
                }
            }
        }
    }

    fn is_complete(&self) -> bool {
        !self.dead && self.mode == Mode::Done
    }

    /// Exact length in bytes of the shortest suffix completing the
    /// document from here (`usize::MAX` when dead). Every open container
    /// costs one close byte; the mode adds what it still owes before the
    /// closes can begin. The cheapest value is a single digit.
    fn min_to_complete(&self) -> usize {
        if self.dead {
            return usize::MAX;
        }
        let mode_cost = match &self.mode {
            Mode::Start => 2, // `{}` or `[]`
            Mode::Done => return 0,
            Mode::ObjFirst | Mode::ObjNext | Mode::ArrFirst | Mode::ArrNext => 0,
            Mode::ObjKey => 4,   // `""`, `:`, digit
            Mode::ObjColon => 2, // `:`, digit
            Mode::ObjValue | Mode::ArrValue => 1,
            Mode::Str { key, esc } => {
                let pending = match esc {
                    Esc::None => 0,
                    Esc::Slash => 1,
                };
                // close quote, plus `:` + digit if this string is a key
                pending + 1 + if *key { 2 } else { 0 }
            }
            Mode::Num(st) => match st {
                // terminal: the next byte can already be a close/delimiter
                NumSt::Zero | NumSt::Int | NumSt::Frac | NumSt::ExpDigits => 0,
                // one digit away from terminal
                NumSt::Minus | NumSt::Dot | NumSt::Exp | NumSt::ExpSign => 1,
            },
            Mode::Lit { word, pos } => word.len() - pos,
        };
        mode_cost + self.stack.len()
    }
}

/// Byte expansion of the serving vocabulary for grammar masking: token ids
/// `0..=255` decode to their own byte (the tokenizer's id space); any
/// higher id gets an empty expansion, which the mask never allows.
pub fn byte_vocab(vocab_size: usize) -> Vec<Vec<u8>> {
    (0..vocab_size)
        .map(|i| if i < 256 { vec![i as u8] } else { Vec::new() })
        .collect()
}

/// Per-request grammar cursor, advanced once per *committed* token.
#[derive(Clone, Debug)]
pub struct GrammarState {
    js: JsonMachine,
}

impl GrammarState {
    pub fn new(c: Constraint) -> Self {
        match c {
            Constraint::Json => Self { js: JsonMachine::new() },
        }
    }

    /// The document is complete; the scheduler finishes the request
    /// (reported as EOS).
    pub fn is_complete(&self) -> bool {
        self.js.is_complete()
    }

    /// Minimal tokens still needed to complete (tokens == bytes for the
    /// byte-level vocab).
    pub fn min_to_complete(&self) -> usize {
        self.js.min_to_complete()
    }

    /// Would emitting `bytes` keep the document on a path that can still
    /// complete within `budget_left` further tokens?
    pub fn token_allowed(&self, bytes: &[u8], budget_left: usize) -> bool {
        if bytes.is_empty() {
            return false;
        }
        let mut probe = self.js.clone();
        for &b in bytes {
            if !probe.accept_byte(b) {
                return false;
            }
        }
        probe.min_to_complete() <= budget_left
    }

    /// Advance past a committed token. Out-of-vocab ids kill the machine
    /// (they can only arrive through unconstrained paths).
    pub fn advance_token(&mut self, tok: u32, vocab: &[Vec<u8>]) {
        match vocab.get(tok as usize) {
            Some(bytes) if !bytes.is_empty() => {
                for &b in bytes.iter() {
                    if !self.js.accept_byte(b) {
                        break;
                    }
                }
            }
            _ => self.js.kill(),
        }
    }

    /// Mask a logits row: disallowed tokens → −∞, allowed tokens pass
    /// through untouched. `budget_left` is how many more tokens the
    /// request may emit *after* the one being sampled. Returns `None` when
    /// nothing is allowed — a complete document, or a vocab that cannot
    /// express the grammar (the scheduler rejects the latter at
    /// admission).
    pub fn mask_row(&self, row: &[f32], vocab: &[Vec<u8>], budget_left: usize) -> Option<Vec<f32>> {
        if self.is_complete() {
            return None;
        }
        let mut out = vec![f32::NEG_INFINITY; row.len()];
        let mut any = false;
        for (i, &v) in row.iter().enumerate() {
            let bytes = vocab.get(i).map(|b| b.as_slice()).unwrap_or(&[]);
            if self.token_allowed(bytes, budget_left) {
                out[i] = v;
                any = true;
            }
        }
        if any {
            Some(out)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::util::rng::Xoshiro256;

    fn feed(s: &str) -> JsonMachine {
        let mut m = JsonMachine::new();
        for &b in s.as_bytes() {
            m.accept_byte(b);
        }
        m
    }

    #[test]
    fn accepts_complete_documents() {
        for doc in [
            "{}",
            "[]",
            r#"{"a":1}"#,
            r#"[1, -2.5e-3, true, false, null, "x\n\"y\"", {"k":[[]]}]"#,
            " { \"a\" : [ 0 , 0.5 , 1E+2 ] , \"b\" : { } } ",
        ] {
            let m = feed(doc);
            assert!(m.is_complete(), "{doc:?} did not complete: {m:?}");
            assert!(Json::parse(doc).is_ok(), "{doc:?} must parse");
        }
    }

    #[test]
    fn rejects_invalid_continuations() {
        for (prefix, bad) in [
            ("", b'1'),          // top level must be a container
            ("{", b','),
            ("{\"a\"", b'1'),    // colon required
            ("{\"a\":1", b']'),  // wrong closer
            ("[0", b'1'),        // leading zero: 0 is already terminal, digit after it dies
            ("[1.", b','),       // dot needs a digit
            ("[1e", b','),       // exponent needs digit/sign
            ("[tru", b'!'),
            ("[\"", 0x07),       // raw control byte in string
            ("[\"\\", b'q'),     // unknown escape
            ("[\"\\", b'u'),     // \u excluded from the output language
            ("{}", b' '),        // Done accepts nothing
        ] {
            let mut m = feed(prefix);
            assert!(!m.dead, "prefix {prefix:?} should be alive");
            assert!(!m.accept_byte(bad), "{prefix:?} + {bad:?} should die");
            assert!(m.dead);
        }
    }

    #[test]
    fn number_termination_redispatches_delimiter() {
        assert!(feed("[1,2]").is_complete());
        assert!(feed(r#"{"a":0}"#).is_complete());
        assert!(feed("[1 ,2]").is_complete(), "ws after number ends it too");
    }

    #[test]
    fn min_to_complete_is_exact_on_known_states() {
        for (prefix, want) in [
            ("", 2usize),        // {}
            ("{", 1),            // }
            ("{\"a", 4),         // "  :  digit  }
            ("{\"a\"", 3),       // :  digit  }
            ("{\"a\":", 2),      // digit  }
            ("{\"a\":1", 1),     // }
            ("[[", 2),           // ]]
            ("[1e", 2),          // digit ]
            ("[tr", 3),          // ue ]
            ("{},", usize::MAX), // dead
            ("{}", 0),
        ] {
            let m = feed(prefix);
            assert_eq!(m.min_to_complete(), want, "prefix {prefix:?}");
            // cross-check: the claimed minimum is achievable — greedily
            // follow any allowed byte that doesn't increase the bound
            if want != 0 && want != usize::MAX {
                let mut m = m;
                let mut steps = 0;
                while !m.is_complete() {
                    let cur = m.min_to_complete();
                    let b = (0u8..=255)
                        .find(|&b| {
                            let mut p = m.clone();
                            p.accept_byte(b) && p.min_to_complete() == cur - 1
                        })
                        .unwrap_or_else(|| panic!("stuck at {m:?} (prefix {prefix:?})"));
                    m.accept_byte(b);
                    steps += 1;
                    assert!(steps <= want, "overran bound on {prefix:?}");
                }
                assert_eq!(steps, want, "prefix {prefix:?} bound not tight");
            }
        }
    }

    #[test]
    fn budget_rule_blocks_openers_it_cannot_close() {
        let g = GrammarState::new(Constraint::Json);
        let vocab = byte_vocab(256);
        // '{' needs one more token ('}') after it
        assert!(g.token_allowed(b"{", 1));
        assert!(!g.token_allowed(b"{", 0));
        // fresh mask with budget 1 admits nothing (no 1-token document)
        assert!(g.mask_row(&vec![0.0; 256], &vocab, 0).is_none());
        let m = g.mask_row(&vec![0.0; 256], &vocab, 1).expect("budget 1 after opener");
        for (i, &v) in m.iter().enumerate() {
            let ok = v > f32::NEG_INFINITY;
            assert_eq!(ok, i == b'{' as usize || i == b'[' as usize, "token {i}");
        }
    }

    #[test]
    fn mask_allows_exactly_the_valid_continuations() {
        let mut g = GrammarState::new(Constraint::Json);
        let vocab = byte_vocab(256);
        for &b in b"{\"k\":".iter() {
            g.advance_token(b as u32, &vocab);
        }
        let m = g.mask_row(&vec![0.0; 256], &vocab, 64).unwrap();
        let allowed: Vec<u8> = (0..256).filter(|&i| m[i] > f32::NEG_INFINITY).map(|i| i as u8).collect();
        for b in [b'"', b'{', b'[', b'0', b'9', b'-', b't', b'f', b'n', b' '] {
            assert!(allowed.contains(&b), "{} should be allowed", b as char);
        }
        for b in [b'}', b']', b',', b':', b'x', 0x07] {
            assert!(!allowed.contains(&b), "{} should be masked", b as char);
        }
    }

    #[test]
    fn completion_reported_and_mask_closes() {
        let mut g = GrammarState::new(Constraint::Json);
        let vocab = byte_vocab(256);
        for &b in b"[1]".iter() {
            g.advance_token(b as u32, &vocab);
        }
        assert!(g.is_complete());
        assert!(g.mask_row(&vec![0.0; 256], &vocab, 64).is_none());
    }

    #[test]
    fn ids_past_255_are_never_allowed() {
        let g = GrammarState::new(Constraint::Json);
        let vocab = byte_vocab(1024);
        let m = g.mask_row(&vec![0.0; 1024], &vocab, 64).unwrap();
        assert!(m[256..].iter().all(|&v| v == f32::NEG_INFINITY));
    }

    /// The induction the scheduler relies on: from a fresh machine, any
    /// walk that always picks *some* allowed token under a shrinking
    /// budget completes within the budget and parses. Randomize the pick
    /// to explore deep nesting, strings, escapes, and numbers.
    #[test]
    fn random_masked_walks_always_complete_and_parse() {
        let vocab = byte_vocab(256);
        let mut rng = Xoshiro256::seed_from_u64(2026);
        for case in 0..40u64 {
            let budget = 2 + (case as usize % 30);
            let mut g = GrammarState::new(Constraint::Json);
            let mut out: Vec<u8> = Vec::new();
            while out.len() < budget && !g.is_complete() {
                let budget_left = budget - out.len() - 1;
                let allowed: Vec<u8> = (0u16..256)
                    .filter(|&i| g.token_allowed(&[i as u8], budget_left))
                    .map(|i| i as u8)
                    .collect();
                assert!(!allowed.is_empty(), "empty mask at {out:?} budget_left={budget_left}");
                let b = allowed[rng.next_below(allowed.len() as u64) as usize];
                g.advance_token(b as u32, &vocab);
                out.push(b);
            }
            assert!(g.is_complete(), "budget {budget} walk did not complete: {out:?}");
            let text = String::from_utf8_lossy(&out);
            assert!(Json::parse(&text).is_ok(), "walk output does not parse: {text}");
        }
    }

    #[test]
    fn constraint_parse_roundtrip() {
        assert_eq!(Constraint::parse("json"), Some(Constraint::Json));
        assert_eq!(Constraint::Json.name(), "json");
        assert_eq!(Constraint::parse("yaml"), None);
    }
}
