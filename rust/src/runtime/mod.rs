//! PJRT runtime: load the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and serve them behind the [`Engine`] trait.
//!
//! Flow: `Artifacts::load` parses the per-variant `manifest.json`,
//! [`PjrtEngine::boot`] compiles each lowered function on the PJRT CPU
//! client and uploads the weight matrices **once** as device buffers (in
//! the manifest's canonical flat order — the same order
//! `model::weights_io` stores). Per step only the small tokens/pos arrays
//! and the padded KV caches cross the host↔device boundary
//! (`execute_b` with the persistent weight buffers).
//!
//! Python never runs at serving time: the rust binary + `artifacts/` are
//! self-contained.
//!
//! [`Engine`]: crate::coordinator::Engine

pub mod artifacts;
pub mod engine;

pub use artifacts::{Artifacts, FunctionMeta};
pub use engine::PjrtEngine;
