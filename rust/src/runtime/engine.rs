//! [`PjrtEngine`] — the AOT path: serve the JAX/Pallas-lowered artifacts
//! through the PJRT CPU client behind the same [`Engine`] trait the CPU
//! engine implements, so the coordinator cannot tell them apart.
//!
//! Weight buffers are uploaded once at boot; each step sends only tokens,
//! positions and the padded per-sequence KV caches. PJRT returns tuple
//! outputs as a single tuple buffer (probed; see DESIGN.md §Runtime), so
//! each step does one `to_literal_sync` + `decompose_tuple` round-trip —
//! fine on the CPU plugin where device memory *is* host memory.

use crate::config::ModelConfig;
use crate::coordinator::engine::{DecodeInput, Engine, EngineError};
use crate::kvcache::SeqId;
use crate::model::{weights_io, ModelWeights};
use crate::runtime::artifacts::Artifacts;
use std::collections::BTreeMap;
use std::path::Path;

struct SeqCache {
    /// (L, S, e) flattened, rotated keys.
    k: Vec<f32>,
    /// (L, S, e) flattened, raw values.
    v: Vec<f32>,
    pos: usize,
}

pub struct PjrtEngine {
    client: xla::PjRtClient,
    artifacts: Artifacts,
    weight_bufs: Vec<xla::PjRtBuffer>,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    seqs: BTreeMap<SeqId, SeqCache>,
    next_id: u64,
    max_seqs: usize,
    cache_elems: usize, // L * S * e
    /// Total weight bytes uploaded at boot (always f32 — metrics only).
    weight_bytes: u64,
}

fn backend(e: impl std::fmt::Display) -> EngineError {
    EngineError::Backend(e.to_string())
}

impl PjrtEngine {
    /// Compile every function in `artifact_dir` and upload `weights`.
    ///
    /// `weights` must match the manifest's (config, variant) — boot fails
    /// loudly on any mismatch rather than silently serving garbage.
    pub fn boot(artifact_dir: &Path, weights: &ModelWeights, max_seqs: usize) -> Result<Self, EngineError> {
        let artifacts = Artifacts::load(artifact_dir).map_err(backend)?;
        if artifacts.cfg != weights.cfg {
            return Err(EngineError::Backend(format!(
                "artifact config '{}' != weight config '{}'",
                artifacts.cfg.name, weights.cfg.name
            )));
        }
        if artifacts.variant != weights.variant {
            return Err(EngineError::Backend(format!(
                "artifact variant {:?} != weight variant {:?}",
                artifacts.variant, weights.variant
            )));
        }
        let client = xla::PjRtClient::cpu().map_err(backend)?;

        // Upload weights in canonical order, cross-checking the manifest.
        let entries = weights_io::flat_entries(weights);
        if entries.len() != artifacts.weights.len() {
            return Err(EngineError::Backend(format!(
                "weight count mismatch: model {} vs manifest {}",
                entries.len(),
                artifacts.weights.len()
            )));
        }
        let mut weight_bufs = Vec::with_capacity(entries.len());
        for ((name, entry), (mname, mshape)) in entries.iter().zip(&artifacts.weights) {
            // The AOT artifacts were lowered for f32 operands; INT8 models
            // are a CPU-engine feature for now.
            let weights_io::EntryRef::F32(mat) = entry else {
                return Err(EngineError::Backend(format!(
                    "PJRT engine requires f32 weights; '{name}' is int8 — serve quantized models with the CPU engine"
                )));
            };
            if name != mname || mat.shape() != (mshape[0], mshape[1]) {
                return Err(EngineError::Backend(format!(
                    "weight order/shape mismatch: model has {name}{:?}, manifest expects {mname}{mshape:?}",
                    mat.shape()
                )));
            }
            let buf = client
                .buffer_from_host_buffer(mat.as_slice(), &[mshape[0], mshape[1]], None)
                .map_err(backend)?;
            weight_bufs.push(buf);
        }

        // Compile all functions.
        let mut prefill_exes = BTreeMap::new();
        let mut decode_exes = BTreeMap::new();
        for f in artifacts.functions.values() {
            let proto = xla::HloModuleProto::from_text_file(
                f.file.to_str().ok_or_else(|| EngineError::Backend("bad path".into()))?,
            )
            .map_err(backend)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(backend)?;
            crate::log_info!("compiled {} ({})", f.name, f.kind);
            match f.kind.as_str() {
                "prefill" => {
                    prefill_exes.insert(f.t, exe);
                }
                "decode" => {
                    decode_exes.insert(f.batch, exe);
                }
                other => return Err(EngineError::Backend(format!("unknown fn kind {other}"))),
            }
        }
        if prefill_exes.is_empty() || decode_exes.is_empty() {
            return Err(EngineError::Backend(
                "artifacts must provide at least one prefill and one decode function".into(),
            ));
        }
        let cfg = &artifacts.cfg;
        let cache_elems = cfg.n_layers * cfg.max_seq_len * cfg.e();
        Ok(Self {
            client,
            artifacts,
            weight_bufs,
            prefill_exes,
            decode_exes,
            seqs: BTreeMap::new(),
            next_id: 0,
            max_seqs,
            cache_elems,
            weight_bytes: weights.stored_bytes(),
        })
    }

    pub fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    /// Smallest prefill bucket ≥ len.
    fn prefill_bucket(&self, len: usize) -> Option<usize> {
        self.prefill_exes.keys().copied().find(|&t| t >= len)
    }

    /// Smallest decode bucket ≥ n.
    fn decode_bucket(&self, n: usize) -> Option<usize> {
        self.decode_exes.keys().copied().find(|&b| b >= n)
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>, EngineError> {
        let out = exe.execute_b(args).map_err(backend)?;
        let lit = out[0][0].to_literal_sync().map_err(backend)?;
        lit.to_tuple().map_err(backend)
    }
}

impl Engine for PjrtEngine {
    fn cfg(&self) -> &ModelConfig {
        &self.artifacts.cfg
    }

    fn describe(&self) -> String {
        format!("pjrt/{}", self.artifacts.variant.name())
    }

    fn weight_bytes(&self) -> (u64, u64) {
        // PJRT weights are always f32: resident == f32-equivalent
        (self.weight_bytes, self.weight_bytes)
    }

    fn can_admit(&self, prompt_len: usize) -> bool {
        self.seqs.len() < self.max_seqs && self.prefill_bucket(prompt_len).is_some()
    }

    fn max_batch(&self) -> usize {
        self.decode_exes.keys().copied().max().unwrap_or(1)
    }

    fn prefill(&mut self, tokens: &[u32]) -> Result<(SeqId, Vec<f32>), EngineError> {
        if tokens.is_empty() {
            return Err(EngineError::BadSequence("empty prompt".into()));
        }
        if self.seqs.len() >= self.max_seqs {
            return Err(EngineError::CapacityExhausted(format!(
                "{} sequences live (max {})",
                self.seqs.len(),
                self.max_seqs
            )));
        }
        let bucket = self.prefill_bucket(tokens.len()).ok_or_else(|| {
            EngineError::CapacityExhausted(format!(
                "prompt length {} exceeds largest prefill bucket {:?}",
                tokens.len(),
                self.prefill_exes.keys().next_back()
            ))
        })?;
        // pad with token 0 — causal masking makes padded rows irrelevant to
        // rows < len, and their cache slots get overwritten by decode.
        let mut padded = vec![0i32; bucket];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&padded, &[bucket], None)
            .map_err(backend)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf];
        args.extend(self.weight_bufs.iter());
        let exe = &self.prefill_exes[&bucket];
        let outs = Self::run(exe, &args)?;
        // outputs: logits (t, vocab), k (L, S, e), v (L, S, e)
        let vocab = self.artifacts.cfg.vocab_size;
        let logits_all = outs[0].to_vec::<f32>().map_err(backend)?;
        let last = tokens.len() - 1;
        let logits = logits_all[last * vocab..(last + 1) * vocab].to_vec();
        let k = outs[1].to_vec::<f32>().map_err(backend)?;
        let v = outs[2].to_vec::<f32>().map_err(backend)?;
        debug_assert_eq!(k.len(), self.cache_elems);
        let id = SeqId(self.next_id);
        self.next_id += 1;
        self.seqs.insert(
            id,
            SeqCache {
                k,
                v,
                pos: tokens.len(),
            },
        );
        Ok((id, logits))
    }

    fn decode_batch(&mut self, inputs: &[DecodeInput]) -> Result<Vec<Vec<f32>>, EngineError> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let n = inputs.len();
        let bucket = self.decode_bucket(n).ok_or_else(|| {
            EngineError::CapacityExhausted(format!(
                "batch {n} exceeds largest decode bucket {}",
                self.max_batch()
            ))
        })?;
        let cfg = self.artifacts.cfg.clone();
        let (ls, se, e) = (cfg.n_layers, cfg.max_seq_len * cfg.e(), cfg.e());
        let _ = e;
        // validate sequences and positions first
        for inp in inputs {
            let s = self
                .seqs
                .get(&inp.seq)
                .ok_or_else(|| EngineError::BadSequence(format!("{:?} not live", inp.seq)))?;
            if s.pos >= cfg.max_seq_len {
                return Err(EngineError::CapacityExhausted(format!(
                    "{:?} at max_seq_len",
                    inp.seq
                )));
            }
        }
        // assemble (B,) tokens & pos, (L, B, S, e) caches; pad rows replicate
        // sequence 0 (their outputs are discarded).
        let pick = |i: usize| -> &SeqCache { &self.seqs[&inputs[i.min(n - 1)].seq] };
        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for b in 0..bucket {
            let inp = &inputs[b.min(n - 1)];
            tokens[b] = inp.token as i32;
            pos[b] = pick(b).pos as i32;
        }
        let mut kbig = vec![0f32; ls * bucket * se];
        let mut vbig = vec![0f32; ls * bucket * se];
        for l in 0..ls {
            for b in 0..bucket {
                let s = pick(b);
                let dst = (l * bucket + b) * se;
                kbig[dst..dst + se].copy_from_slice(&s.k[l * se..(l + 1) * se]);
                vbig[dst..dst + se].copy_from_slice(&s.v[l * se..(l + 1) * se]);
            }
        }
        let tok_buf = self
            .client
            .buffer_from_host_buffer(&tokens, &[bucket], None)
            .map_err(backend)?;
        let pos_buf = self
            .client
            .buffer_from_host_buffer(&pos, &[bucket], None)
            .map_err(backend)?;
        let dims = [ls, bucket, cfg.max_seq_len, cfg.e()];
        let k_buf = self
            .client
            .buffer_from_host_buffer(&kbig, &dims, None)
            .map_err(backend)?;
        let v_buf = self
            .client
            .buffer_from_host_buffer(&vbig, &dims, None)
            .map_err(backend)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &k_buf, &v_buf];
        args.extend(self.weight_bufs.iter());
        let outs = Self::run(&self.decode_exes[&bucket], &args)?;

        let vocab = cfg.vocab_size;
        let logits_all = outs[0].to_vec::<f32>().map_err(backend)?;
        let k_new = outs[1].to_vec::<f32>().map_err(backend)?;
        let v_new = outs[2].to_vec::<f32>().map_err(backend)?;
        // scatter caches back + advance positions (real rows only)
        for (b, inp) in inputs.iter().enumerate() {
            let s = self.seqs.get_mut(&inp.seq).unwrap();
            for l in 0..ls {
                let src = (l * bucket + b) * se;
                s.k[l * se..(l + 1) * se].copy_from_slice(&k_new[src..src + se]);
                s.v[l * se..(l + 1) * se].copy_from_slice(&v_new[src..src + se]);
            }
            s.pos += 1;
        }
        Ok((0..n)
            .map(|b| logits_all[b * vocab..(b + 1) * vocab].to_vec())
            .collect())
    }

    fn release(&mut self, seq: SeqId) {
        self.seqs.remove(&seq);
    }
}
