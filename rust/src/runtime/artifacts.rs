//! Artifact manifest parsing (`artifacts/<preset>/<variant>/manifest.json`).

use crate::config::{ModelConfig, Variant};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered function's signature.
#[derive(Clone, Debug)]
pub struct FunctionMeta {
    pub name: String,
    pub file: PathBuf,
    /// "prefill" | "decode"
    pub kind: String,
    /// prefill: padded prompt length; decode: 0.
    pub t: usize,
    /// decode: batch size; prefill: 1.
    pub batch: usize,
    pub max_seq: usize,
    /// Positional input descriptors: (name, role, element count).
    pub inputs: Vec<(String, String, usize)>,
    /// Output element counts (logits, k_cache, v_cache).
    pub outputs: Vec<(String, usize)>,
}

/// A parsed artifact directory for one (config, variant).
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub cfg: ModelConfig,
    pub variant: Variant,
    /// Weight entry (name, shape) in canonical upload order.
    pub weights: Vec<(String, Vec<usize>)>,
    pub functions: BTreeMap<String, FunctionMeta>,
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

impl Artifacts {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> std::io::Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)?;
        let j = Json::parse(&text).map_err(|e| io_err(format!("{manifest_path:?}: {e}")))?;
        let cfg = ModelConfig::from_json(
            j.get("config").ok_or_else(|| io_err("manifest missing config".into()))?,
        )
        .map_err(|e| io_err(e.to_string()))?;
        let variant = j
            .get("variant")
            .and_then(|v| v.as_str())
            .and_then(Variant::parse)
            .ok_or_else(|| io_err("manifest missing variant".into()))?;

        let weights = j
            .get("weights")
            .and_then(|w| w.as_arr())
            .ok_or_else(|| io_err("manifest missing weights".into()))?
            .iter()
            .map(|e| {
                let name = e.get("name").and_then(|n| n.as_str()).unwrap_or("?").to_string();
                let shape: Vec<usize> = e
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                    .unwrap_or_default();
                (name, shape)
            })
            .collect();

        let mut functions = BTreeMap::new();
        let fobj = j
            .get("functions")
            .and_then(|f| f.as_obj())
            .ok_or_else(|| io_err("manifest missing functions".into()))?;
        for (name, meta) in fobj {
            let get_n = |k: &str| meta.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            let inputs = meta
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| io_err(format!("{name}: no inputs")))?
                .iter()
                .map(|inp| {
                    let n = inp.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                    let role = inp.get("role").and_then(|v| v.as_str()).unwrap_or("weight").to_string();
                    let count: usize = inp
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|a| a.iter().filter_map(|v| v.as_usize()).product())
                        .unwrap_or(0);
                    (n, role, count)
                })
                .collect();
            let outputs = meta
                .get("outputs")
                .and_then(|o| o.as_arr())
                .ok_or_else(|| io_err(format!("{name}: no outputs")))?
                .iter()
                .map(|out| {
                    let n = out.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                    let count: usize = out
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|a| a.iter().filter_map(|v| v.as_usize()).product())
                        .unwrap_or(0);
                    (n, count)
                })
                .collect();
            functions.insert(
                name.clone(),
                FunctionMeta {
                    name: name.clone(),
                    file: dir.join(
                        meta.get("file")
                            .and_then(|f| f.as_str())
                            .ok_or_else(|| io_err(format!("{name}: no file")))?,
                    ),
                    kind: meta.get("kind").and_then(|k| k.as_str()).unwrap_or("?").to_string(),
                    t: get_n("t"),
                    batch: get_n("batch").max(1),
                    max_seq: get_n("max_seq"),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            cfg,
            variant,
            weights,
            functions,
        })
    }

    /// Prefill buckets available, ascending.
    pub fn prefill_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .functions
            .values()
            .filter(|f| f.kind == "prefill")
            .map(|f| f.t)
            .collect();
        v.sort_unstable();
        v
    }

    /// Decode batch buckets available, ascending.
    pub fn decode_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .functions
            .values()
            .filter(|f| f.kind == "decode")
            .map(|f| f.batch)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn prefill_fn(&self, t: usize) -> Option<&FunctionMeta> {
        self.functions.get(&format!("prefill_t{t}"))
    }

    pub fn decode_fn(&self, b: usize) -> Option<&FunctionMeta> {
        self.functions.get(&format!("decode_b{b}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Parse a hand-written manifest (no python needed for this test).
    #[test]
    fn parse_minimal_manifest() {
        let dir = std::env::temp_dir().join("skipless_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "config": {"name":"tiny-mha","dim":64,"n_layers":2,"n_heads":4,
            "n_kv_heads":4,"hidden_dim":128,"vocab_size":256,"max_seq_len":128,
            "attention":"mha","layout":"serial","ffn":"mlp","tied_embeddings":false},
          "variant": "merged_qp",
          "weights": [{"name":"embed","shape":[256,64]}],
          "functions": {
            "prefill_t8": {"file":"prefill_t8.hlo.txt","kind":"prefill","t":8,
              "max_seq":128,
              "inputs":[{"name":"tokens","dtype":"s32","shape":[8],"role":"tokens"}],
              "outputs":[{"name":"logits","dtype":"f32","shape":[8,256]}]},
            "decode_b4": {"file":"decode_b4.hlo.txt","kind":"decode","batch":4,
              "max_seq":128,
              "inputs":[{"name":"tokens","dtype":"s32","shape":[4],"role":"tokens"}],
              "outputs":[{"name":"logits","dtype":"f32","shape":[4,256]}]}
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.variant, crate::config::Variant::MergedQP);
        assert_eq!(a.prefill_buckets(), vec![8]);
        assert_eq!(a.decode_buckets(), vec![4]);
        let f = a.prefill_fn(8).unwrap();
        assert_eq!(f.inputs[0].1, "tokens");
        assert_eq!(f.outputs[0].1, 8 * 256);
        assert!(a.decode_fn(2).is_none());
    }

    #[test]
    fn missing_manifest_is_io_error() {
        assert!(Artifacts::load(Path::new("/nonexistent/x")).is_err());
    }
}
