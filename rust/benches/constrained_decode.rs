//! Stochastic speculative + JSON-constrained decoding bench.
//!
//! Two claims, each asserted rather than merely reported:
//!
//! 1. **Stochastic speculation pays.** With per-request seeds, the
//!    speculative run must produce byte-identical streams to the plain
//!    stochastic run (the RNG-stream-discipline invariant) while taking
//!    measurably fewer target batched steps per generated token. The
//!    reduction bar (≥ 1.3x at k=4, full mode) is set at a low sampling
//!    temperature — the realistic regime for speculation, since acceptance
//!    probability is the target's probability of the draft's argmax and
//!    flat distributions make any drafting scheme useless.
//! 2. **Constrained output always parses.** Every `"constrain":"json"`
//!    completion — greedy or stochastic, plain or speculative — must parse
//!    as a JSON document and finish via grammar completion, and the
//!    speculative streams must equal the plain ones.
//!
//! Emits `BENCH_constrained.json` (schema in EXPERIMENTS.md);
//! `SKIPLESS_BENCH_QUICK=1` shrinks the model and token counts for CI.

use skipless::config::{AttentionKind, BlockLayout, FfnKind, ModelConfig};
use skipless::coordinator::{CpuEngine, FinishReason, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::CacheOpts;
use skipless::metrics::Metrics;
use skipless::model::{quantize, ModelWeights};
use skipless::sampler::grammar::Constraint;
use skipless::sampler::SamplerCfg;
use skipless::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Same mid-size GQA model as `spec_decode`: big enough that decode is
/// genuinely weight-streaming-bound, small enough to init in seconds.
fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "spec-bench-85m".into(),
        dim: 384,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 2,
        hidden_dim: 1536,
        vocab_size: 1024,
        max_seq_len: 512,
        attention: AttentionKind::Gqa,
        layout: BlockLayout::Serial,
        ffn: FfnKind::Mlp,
        tied_embeddings: false,
    }
}

struct RunStats {
    tokens: Vec<Vec<u32>>,
    finishes: Vec<FinishReason>,
    target_steps: u64,
    tokens_decoded: u64,
    drafted: u64,
    accepted: u64,
    wall_s: f64,
}

fn run(w: &ModelWeights, spec_k: usize, reqs: &[Request], budget: usize) -> RunStats {
    let metrics = Arc::new(Metrics::new());
    let cfg = SchedulerCfg {
        spec_k,
        ..Default::default()
    };
    let engine = CpuEngine::new(w.clone(), 16, budget);
    let mut s = if spec_k > 0 {
        let draft = CpuEngine::with_cache_opts(
            quantize(w),
            16,
            budget,
            CacheOpts {
                quantized: true,
                ..Default::default()
            },
        );
        Scheduler::with_draft(engine, Box::new(draft), cfg, Arc::clone(&metrics))
    } else {
        Scheduler::new(engine, cfg, Arc::clone(&metrics))
    };
    for r in reqs {
        s.submit(r.clone());
    }
    let t0 = Instant::now();
    let mut done = s.run_to_completion();
    let wall_s = t0.elapsed().as_secs_f64();
    done.sort_by_key(|r| r.id);
    RunStats {
        finishes: done.iter().map(|r| r.finish).collect(),
        tokens: done.into_iter().map(|r| r.tokens).collect(),
        target_steps: metrics.batches_run.load(Ordering::Relaxed),
        tokens_decoded: metrics.tokens_decoded.load(Ordering::Relaxed),
        drafted: metrics.spec_tokens_drafted.load(Ordering::Relaxed),
        accepted: metrics.spec_tokens_accepted.load(Ordering::Relaxed),
        wall_s,
    }
}

fn base_reqs(n: usize, max_new: usize, vocab: u32, temperature: f32) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let prompt = (0..6).map(|j| ((i * 131 + j * 17 + 3) as u32) % vocab).collect();
            let mut r = Request::greedy(i as u64, prompt, max_new);
            // fixed per-request seeds: what makes spec vs plain comparable
            // stream-for-stream
            r.seed = 0xC0FF_EE00 + 7919 * i as u64;
            if temperature > 0.0 {
                r.sampler = SamplerCfg {
                    temperature,
                    ..Default::default()
                };
            }
            r
        })
        .collect()
}

fn constrained_reqs(n: usize, max_new: usize, vocab: u32, temperature: f32) -> Vec<Request> {
    base_reqs(n, max_new, vocab, temperature)
        .into_iter()
        .map(|mut r| {
            r.constrain = Some(Constraint::Json);
            r
        })
        .collect()
}

/// Every constrained stream must decode (byte vocab), parse as JSON, and
/// have finished via grammar completion.
fn assert_all_parse(label: &str, stats: &RunStats) {
    for (i, (t, f)) in stats.tokens.iter().zip(&stats.finishes).enumerate() {
        assert_eq!(
            *f,
            FinishReason::Eos,
            "{label}: constrained request {i} must finish via grammar completion"
        );
        let bytes: Vec<u8> = t
            .iter()
            .map(|&x| u8::try_from(x).expect("constrained tokens are byte-vocab"))
            .collect();
        let text = String::from_utf8_lossy(&bytes).into_owned();
        Json::parse(&text)
            .unwrap_or_else(|e| panic!("{label}: request {i} output {text:?} must parse: {e}"));
    }
}

fn steps_per_token(s: &RunStats) -> f64 {
    s.target_steps as f64 / s.tokens_decoded.max(1) as f64
}

fn main() {
    println!("# constrained_decode — stochastic speculative + JSON-constrained decoding");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = if quick { ModelConfig::tiny_gqa() } else { bench_config() };
    let (n_req, max_new) = if quick { (4, 12) } else { (8, 32) };
    let k = 4usize;
    let budget = 64 << 20;
    // low temperature = the regime where speculation helps: acceptance is
    // the target's probability of the draft's argmax
    let spec_temp = 0.2f32;

    eprintln!("  initializing {} (this includes calibration)...", cfg.name);
    let w = ModelWeights::init_vanilla(&cfg, 2026);
    let vocab = cfg.vocab_size as u32;

    // ---- part 1: stochastic speculative decoding --------------------
    let sreqs = base_reqs(n_req, max_new, vocab, spec_temp);
    let plain = run(&w, 0, &sreqs, budget);
    let spec = run(&w, k, &sreqs, budget);
    assert_eq!(
        plain.tokens, spec.tokens,
        "stochastic speculative decode diverged from plain stochastic decode \
         for fixed seeds (RNG stream discipline broken)"
    );
    let spt_plain = steps_per_token(&plain);
    let spt_spec = steps_per_token(&spec);
    let reduction = spt_plain / spt_spec;
    let accept_rate = spec.accepted as f64 / spec.drafted.max(1) as f64;
    eprintln!(
        "  stochastic t={spec_temp}: plain {:.4} steps/tok vs spec {:.4} steps/tok \
         → {reduction:.2}x reduction, accept {:.1}% ({}/{})",
        spt_plain,
        spt_spec,
        100.0 * accept_rate,
        spec.accepted,
        spec.drafted
    );
    println!(
        "{{\"suite\":\"constrained_decode\",\"case\":\"stochastic_spec_k{k}\",\"temperature\":{spec_temp},\"steps_per_token_plain\":{spt_plain:.4},\"steps_per_token_spec\":{spt_spec:.4},\"target_step_reduction_x\":{reduction:.4},\"accept_rate\":{accept_rate:.4}}}"
    );
    // acceptance bar (full mode): ≥ 1.3x fewer target batched steps per
    // generated token at k=4 under stochastic acceptance
    if !quick {
        assert!(
            reduction >= 1.3,
            "stochastic target-step reduction only {reduction:.2}x at k={k}"
        );
    }

    // ---- part 2: constrained decoding, every mode -------------------
    let mut cases = Vec::new();
    for (case, temp) in [("greedy", 0.0f32), ("stochastic", 0.9f32)] {
        let creqs = constrained_reqs(n_req, max_new.max(16), vocab, temp);
        let cp = run(&w, 0, &creqs, budget);
        let cs = run(&w, k, &creqs, budget);
        assert_eq!(
            cp.tokens, cs.tokens,
            "constrained/{case}: speculative decode diverged from plain"
        );
        assert_all_parse(&format!("constrained/{case}/plain"), &cp);
        assert_all_parse(&format!("constrained/{case}/speculative"), &cs);
        let ar = cs.accepted as f64 / cs.drafted.max(1) as f64;
        eprintln!(
            "  constrained/{case}: {} requests, all parse, spec ≡ plain, accept {:.1}%",
            creqs.len(),
            100.0 * ar
        );
        println!(
            "{{\"suite\":\"constrained_decode\",\"case\":\"constrained_{case}\",\"all_parse\":true,\"identical_output\":true,\"accept_rate\":{ar:.4}}}"
        );
        cases.push(format!(
            "    {{\n      \"case\": \"{case}\",\n      \"temperature\": {temp},\n      \"requests\": {},\n      \"all_parse\": true,\n      \"identical_output\": true,\n      \"accept_rate\": {ar:.4},\n      \"steps_per_token_plain\": {:.4},\n      \"steps_per_token_spec\": {:.4},\n      \"wall_plain_s\": {:.4},\n      \"wall_spec_s\": {:.4}\n    }}",
            creqs.len(),
            steps_per_token(&cp),
            steps_per_token(&cs),
            cp.wall_s,
            cs.wall_s,
        ));
    }

    let json = format!(
        "{{\n  \"suite\": \"constrained_decode\",\n  \"model\": \"{}\",\n  \"k\": {k},\n  \"requests\": {n_req},\n  \"max_new_tokens\": {max_new},\n  \"stochastic\": {{\n    \"temperature\": {spec_temp},\n    \"identical_output\": true,\n    \"accept_rate\": {accept_rate:.4},\n    \"steps_per_token_plain\": {spt_plain:.4},\n    \"steps_per_token_spec\": {spt_spec:.4},\n    \"target_step_reduction_x\": {reduction:.4},\n    \"wall_plain_s\": {:.4},\n    \"wall_spec_s\": {:.4}\n  }},\n  \"constrained\": [\n{}\n  ]\n}}\n",
        cfg.name,
        plain.wall_s,
        spec.wall_s,
        cases.join(",\n"),
    );
    std::fs::write("BENCH_constrained.json", &json).expect("write BENCH_constrained.json");
    eprintln!("  wrote BENCH_constrained.json");
}
