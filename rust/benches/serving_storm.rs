//! Serving storm bench: one reactor thread versus a thousand connections.
//!
//! The poll-based front-end exists so that idle connections cost a poll
//! slot instead of a parked thread, and so that a slow reader throttles
//! only its own stream. This bench opens a large population of idle
//! connections, parks a few deliberately slow streaming readers behind
//! them, and then drives a burst of active streaming requests through the
//! same single reactor, measuring client-observed TTFB (send → first
//! token frame) and the server's write-queue high-water mark.
//!
//! Full mode asserts the serving SLOs: p99 TTFB stays bounded with ≥1k
//! connections open, the per-connection write queue never exceeds its cap
//! plus one frame (the backpressure invariant), and every stream —
//! including the slow readers' — arrives complete and ordered. Emits
//! `BENCH_serving.json` (schema in EXPERIMENTS.md);
//! `SKIPLESS_BENCH_QUICK=1` shrinks the population for CI.

use skipless::config::ModelConfig;
use skipless::coordinator::{Coordinator, CpuEngine, SchedulerCfg};
use skipless::metrics::Metrics;
use skipless::model::ModelWeights;
use skipless::server::{generate_req, Client, Server, ServerCfg};
use skipless::util::json::Json;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Raise the open-file-descriptor soft limit toward `want` (each
/// connection costs two descriptors in this single-process bench). Returns
/// the effective soft limit.
#[cfg(target_os = "linux")]
fn raise_nofile(want: u64) -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    const RLIMIT_NOFILE: i32 = 7;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    let mut r = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut r) } != 0 {
        return 1024;
    }
    if r.cur < want {
        let bumped = RLimit { cur: want.min(r.max), max: r.max };
        unsafe { setrlimit(RLIMIT_NOFILE, &bumped) };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut r) } != 0 {
            return 1024;
        }
    }
    r.cur
}

#[cfg(not(target_os = "linux"))]
fn raise_nofile(_want: u64) -> u64 {
    1024
}

fn add_stream(req: &mut Json) {
    if let Json::Obj(o) = req {
        o.insert("stream".into(), Json::Bool(true));
    }
}

fn percentile(xs: &[u64], q: f64) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    v[((v.len() as f64 * q).ceil() as usize).saturating_sub(1).min(v.len() - 1)]
}

/// Drain one streaming reply, optionally throttling between frames.
/// Returns (ttfb, streamed tokens, final object).
fn drain_stream(
    c: &mut Client,
    sent_at: Instant,
    frame_delay: Duration,
) -> (Duration, Vec<u32>, Json) {
    let mut ttfb = None;
    let mut streamed = Vec::new();
    loop {
        let frame = c.read_reply().expect("stream frame");
        ttfb.get_or_insert_with(|| sent_at.elapsed());
        if frame.get("event").and_then(|e| e.as_str()) == Some("token") {
            streamed.push(frame.get("token").unwrap().as_u64().unwrap() as u32);
            if !frame_delay.is_zero() {
                std::thread::sleep(frame_delay);
            }
            continue;
        }
        return (ttfb.unwrap(), streamed, frame);
    }
}

fn main() {
    println!("# serving_storm — reactor under idle-connection + slow-reader pressure");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let (idle_target, slow_readers, workers, reqs_per_worker, max_new) =
        if quick { (64usize, 2usize, 4usize, 3usize, 8usize) } else { (1000, 4, 8, 25, 32) };

    // two fds per in-process connection (client + server end) plus headroom
    let limit = raise_nofile((2 * idle_target + 512) as u64);
    let idle_n = idle_target.min((limit.saturating_sub(256) / 2) as usize);
    if idle_n < idle_target {
        eprintln!("  NOFILE limit {limit} caps idle connections at {idle_n} (wanted {idle_target})");
    }

    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 3031);
    let write_queue_cap = 4096usize;
    let coord = Coordinator::spawn(CpuEngine::new(w, 8, 64 << 20), SchedulerCfg::default());
    let metrics: Arc<Metrics> = Arc::clone(coord.metrics());
    let server = Server::bind_with(
        "127.0.0.1:0",
        coord,
        ServerCfg {
            max_conns: idle_n + slow_readers + workers + 64,
            queue_depth: 1024,
            rate_limit: 0.0,
            write_queue_cap,
        },
    )
    .expect("bind");
    let addr = server.local_addr().to_string();
    std::thread::spawn(move || {
        let _ = server.serve();
    });

    // ---- phase 1: a wall of idle connections --------------------------
    // Paced so the listener backlog never overflows between reactor ticks.
    eprintln!("  opening {idle_n} idle connections...");
    let t_idle = Instant::now();
    let mut idle = Vec::with_capacity(idle_n);
    for i in 0..idle_n {
        idle.push(TcpStream::connect(&addr).expect("idle connect"));
        if i % 32 == 31 {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    // ...and prove they are all registered before the storm starts
    let mut probe = Client::connect(&addr).expect("probe connect");
    for _ in 0..400 {
        if metrics.conns_open.load(Ordering::Relaxed) as usize >= idle_n + 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let open_before = metrics.conns_open.load(Ordering::Relaxed);
    assert!(
        open_before as usize >= idle_n + 1,
        "reactor only registered {open_before} of {} connections",
        idle_n + 1
    );
    eprintln!("  {open_before} connections open after {:.2}s", t_idle.elapsed().as_secs_f64());

    // ---- phase 2: slow readers + active streaming burst ----------------
    let wall = Instant::now();
    let slow_handles: Vec<_> = (0..slow_readers)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("slow connect");
                let mut req = generate_req(&[1, 2, 3], max_new);
                add_stream(&mut req);
                let t0 = Instant::now();
                c.send(&req).expect("slow send");
                // a reader an order of magnitude slower than generation:
                // its stream must still arrive complete, throttling no one
                let (_, streamed, fin) = drain_stream(&mut c, t0, Duration::from_millis(15));
                assert_eq!(fin.get("finish").unwrap().as_str(), Some("length"));
                assert_eq!(streamed.len(), max_new, "slow reader lost frames");
                streamed.len() as u64
            })
        })
        .collect();

    let worker_handles: Vec<_> = (0..workers)
        .map(|wi| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("worker connect");
                let mut ttfb_us = Vec::with_capacity(reqs_per_worker);
                let mut tokens = 0u64;
                for ri in 0..reqs_per_worker {
                    let prompt = [1 + wi as u32, 2 + ri as u32, 3];
                    let mut req = generate_req(&prompt, max_new);
                    add_stream(&mut req);
                    let t0 = Instant::now();
                    c.send(&req).expect("worker send");
                    let (ttfb, streamed, fin) = drain_stream(&mut c, t0, Duration::ZERO);
                    assert_eq!(fin.get("finish").unwrap().as_str(), Some("length"));
                    let final_tokens: Vec<u32> = fin
                        .get("tokens")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .filter_map(|v| v.as_u64().map(|t| t as u32))
                        .collect();
                    assert_eq!(streamed, final_tokens, "stream diverged from final reply");
                    tokens += streamed.len() as u64;
                    ttfb_us.push(ttfb.as_micros() as u64);
                }
                (ttfb_us, tokens)
            })
        })
        .collect();

    let mut ttfb_us: Vec<u64> = Vec::new();
    let mut tokens_streamed = 0u64;
    for h in worker_handles {
        let (t, n) = h.join().expect("worker");
        ttfb_us.extend(t);
        tokens_streamed += n;
    }
    for h in slow_handles {
        tokens_streamed += h.join().expect("slow reader");
    }
    let wall_s = wall.elapsed().as_secs_f64();

    // the probe still round-trips: the storm never wedged the reactor
    let pong = probe.call(&Json::obj(vec![("op", Json::str("ping"))])).expect("ping");
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));

    let total_reqs = (workers * reqs_per_worker) as u64;
    let p50 = percentile(&ttfb_us, 0.50);
    let p99 = percentile(&ttfb_us, 0.99);
    let srv_ttfb_p99_us = metrics.ttfb.quantile(0.99).as_micros() as u64;
    let peak = metrics.write_queue_peak_bytes.load(Ordering::Relaxed);
    let residual = metrics.write_queue_bytes.load(Ordering::Relaxed);
    let shed = metrics.requests_shed.load(Ordering::Relaxed);
    eprintln!(
        "  {total_reqs} streamed requests over {} conns: TTFB p50 {p50}µs  p99 {p99}µs  \
         ({:.1} req/s, {tokens_streamed} tokens)",
        open_before,
        total_reqs as f64 / wall_s
    );
    eprintln!(
        "  write-queue peak {peak}B (cap {write_queue_cap}B), residual {residual}B, shed {shed}"
    );
    println!(
        "{{\"suite\":\"serving\",\"case\":\"storm\",\"conns\":{open_before},\"ttfb_p99_us\":{p99},\"write_queue_peak_bytes\":{peak}}}"
    );

    // the backpressure invariant holds at any scale: cap + one frame
    assert!(
        peak <= (write_queue_cap + 1024) as u64,
        "write queue peak {peak}B exceeded cap {write_queue_cap}B + one frame"
    );
    // every stream fully drained → nothing left buffered server-side
    assert_eq!(residual, 0, "write queues should be empty after the storm");
    assert_eq!(shed, 0, "no request should shed below the configured depth");
    if !quick {
        // SLO: even with 1k+ idle conns and slow readers on the same
        // reactor, first-token latency stays in interactive territory
        assert!(
            p99 < 2_000_000,
            "client p99 TTFB {p99}µs breached the 2s storm SLO"
        );
    }

    let json = format!(
        "{{\n  \"suite\": \"serving\",\n  \"model\": \"{}\",\n  \"idle_conns\": {idle_n},\n  \"conns_open_peak\": {open_before},\n  \"slow_readers\": {slow_readers},\n  \"workers\": {workers},\n  \"requests\": {total_reqs},\n  \"max_new_tokens\": {max_new},\n  \"tokens_streamed\": {tokens_streamed},\n  \"ttfb_p50_us\": {p50},\n  \"ttfb_p99_us\": {p99},\n  \"server_ttfb_p99_us\": {srv_ttfb_p99_us},\n  \"write_queue_cap_bytes\": {write_queue_cap},\n  \"write_queue_peak_bytes\": {peak},\n  \"requests_shed\": {shed},\n  \"throughput_req_per_s\": {:.2},\n  \"wall_s\": {wall_s:.4}\n}}\n",
        cfg.name,
        total_reqs as f64 / wall_s,
    );
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    eprintln!("  wrote BENCH_serving.json");
    drop(idle);
}
