//! Bench for §5 / Fig. 4 (future work): transformers WITH normalization and
//! skip connections, with Q and P removed as an architecture choice.
//!
//! Two questions, two instruments:
//! * **Cost**: forward throughput of the residual block with vs without
//!   Q/P — measured here (the inference benefit carries over: fewer
//!   weights to stream, same token path).
//! * **Quality**: does removing Q/P hurt trainability? That needs
//!   autodiff → `make train-demo` (python/compile/train.py --fig4) trains
//!   both at matched budgets; EXPERIMENTS.md §Fig4 records the loss
//!   curves side by side.

use skipless::config::ModelConfig;
use skipless::model::residual::{init_residual_noqp, prefill_residual};
use skipless::model::ModelWeights;
use skipless::util::bench::{black_box, Bencher};

fn main() {
    println!("# fig4_ablation — residual (+norm, +skips) with/without Q and P");
    let mut cfg = ModelConfig::tiny_mha();
    cfg.n_layers = 8; // depth where skips/norm actually matter
    let full = ModelWeights::init_vanilla(&cfg, 77);
    let noqp = init_residual_noqp(&cfg, 77);
    let saved = full.stored_weights() - noqp.stored_weights();
    eprintln!(
        "residual-noqp removes {} weights (−{:.1}%)",
        saved,
        100.0 * saved as f64 / full.stored_weights() as f64
    );

    let prompt: Vec<u32> = (0..32).map(|i| (i * 13 + 5) % 250).collect();
    // sanity: both run, both finite, and they differ (not equivalent)
    let lf = prefill_residual(&full, &prompt);
    let ln = prefill_residual(&noqp, &prompt);
    assert!(lf.all_finite() && ln.all_finite());
    assert!(lf.max_abs_diff(&ln) > 1e-3, "no-QP must be a different function");
    eprintln!("both forms stable over {} layers ✓ (function differs, as expected)", cfg.n_layers);

    let mut b = Bencher::new("fig4_ablation");
    b.case_items("residual_with_qp_32tok", Some(32.0), || {
        black_box(prefill_residual(&full, &prompt));
    });
    b.case_items("residual_without_qp_32tok", Some(32.0), || {
        black_box(prefill_residual(&noqp, &prompt));
    });
    let r = b.finish();
    let t_full = r[0].median.as_secs_f64();
    let t_noqp = r[1].median.as_secs_f64();
    eprintln!(
        "forward speedup without Q/P: {:.3}x (quality ablation: `make train-demo`)",
        t_full / t_noqp
    );
}
