//! Bench for Fig. 3: parallel-block (GPT-J/Pythia-style) merges.
//!
//! Verifies the carry-merged exact construction for all three variants
//! (DESIGN.md §Parallel) and benchmarks parallel-vs-serial block forward
//! cost, plus the native (train-from-scratch, 2d²-saving) merged form.

use skipless::config::{BlockLayout, ModelConfig, Variant};
use skipless::model::{prefill, ModelWeights};
use skipless::surgery::{transform, Options};
use skipless::util::bench::{black_box, Bencher};

fn main() {
    println!("# fig3_parallel — parallel skipless transformers (paper Fig. 3)");
    let cfg = ModelConfig::tiny_parallel();
    let vanilla = ModelWeights::init_vanilla(&cfg, 888);
    let toks = [5u32, 17, 3, 42, 8, 1];
    let (l0, _) = prefill(&vanilla, &toks);

    eprintln!("\ncarry-merged exact equivalence (C = P·T_next):");
    for v in [Variant::MergedQP, Variant::MergedKP, Variant::MergedVP] {
        let merged = transform(&vanilla, v, Options::default()).unwrap();
        let (l1, _) = prefill(&merged, &toks);
        let err = l1.rel_fro_err(&l0);
        let saved = vanilla.stored_weights() - merged.stored_weights();
        eprintln!(
            "  {:<11} rel err {:>10.3e}  −{saved} weights (d²/block)",
            v.name(),
            err
        );
        assert!(err < 1e-3, "{v:?} violated equivalence: {err}");
    }
    let d2 = (cfg.dim * cfg.dim * cfg.n_layers) as u64;
    let merged = transform(&vanilla, Variant::MergedQP, Options::default()).unwrap();
    assert_eq!(vanilla.stored_weights() - merged.stored_weights(), d2);

    // native Fig-3a form (q and p both absent, no carry): the architecture
    // the §3 table's 2d² accounting assumes — a train-from-scratch model,
    // NOT function-preserving (documented honestly in DESIGN.md).
    let mut native = vanilla.clone();
    native.variant = Variant::MergedQP;
    for blk in &mut native.blocks {
        blk.q = None;
        blk.p = None;
    }
    let (ln, _) = prefill(&native, &toks);
    let err_native = ln.rel_fro_err(&l0);
    eprintln!(
        "\nnative Fig-3a (no Q, no P, no carry): saves 2d²/block but rel err {:.3} — a new \
         architecture, not a transform (trains fine: see fig4_ablation)",
        err_native
    );
    assert!(err_native > 1e-3, "native form should differ from vanilla");

    // forward cost: serial vs parallel block, vanilla vs merged
    let mut b = Bencher::new("fig3_parallel");
    let serial_cfg = ModelConfig::tiny_mha();
    assert_eq!(cfg.layout, BlockLayout::Parallel);
    let serial = ModelWeights::init_vanilla(&serial_cfg, 889);
    let prompt: Vec<u32> = (0..32).map(|i| (i * 7 + 1) % 250).collect();
    b.case_items("prefill_serial_32tok", Some(32.0), || {
        black_box(prefill(&serial, &prompt));
    });
    b.case_items("prefill_parallel_32tok", Some(32.0), || {
        black_box(prefill(&vanilla, &prompt));
    });
    b.case_items("prefill_parallel_carry_merged_32tok", Some(32.0), || {
        black_box(prefill(&merged, &prompt));
    });
    b.case_items("prefill_parallel_native_noqp_32tok", Some(32.0), || {
        black_box(prefill(&native, &prompt));
    });
    b.finish();
}
