//! Bench for the paper's §3 table: regenerates every cell (weight counts,
//! savings, batch-1 speedups) and times the analytic pipeline. The table
//! rows are printed so EXPERIMENTS.md can quote them directly.

use skipless::bandwidth::{predicted_speedup, Hardware};
use skipless::config::{ModelConfig, Variant};
use skipless::params::{batch1_speedup, count_weights, savings_fraction, table3_report};
use skipless::util::bench::{black_box, Bencher};

fn main() {
    println!("# table3 — paper §3 reproduction");
    for preset in ["pythia-6.9b", "mistral-7b"] {
        let cfg = ModelConfig::preset(preset).unwrap();
        eprintln!("{}", table3_report(&cfg));
    }
    // hard assertions: the paper's published cells
    let py = ModelConfig::pythia_6_9b();
    let mi = ModelConfig::mistral_7b();
    assert_eq!(count_weights(&py, Variant::Vanilla).total(), 6_855_327_744);
    assert_eq!(count_weights(&py, Variant::MergedQP).total(), 5_781_585_920);
    assert_eq!(count_weights(&mi, Variant::Vanilla).total(), 7_241_465_856);
    assert_eq!(count_weights(&mi, Variant::MergedQP).total(), 6_167_724_032);
    assert!((savings_fraction(&py, Variant::MergedQP) - 0.16).abs() < 0.01);
    assert!((savings_fraction(&mi, Variant::MergedQP) - 0.15).abs() < 0.01);
    assert!((batch1_speedup(&py, Variant::MergedQP) - 1.19).abs() < 0.01);
    assert!((batch1_speedup(&mi, Variant::MergedQP) - 1.17).abs() < 0.01);
    eprintln!("all §3 cells match the paper ✓");

    let mut b = Bencher::new("table3");
    b.case("count_weights(mistral-7b)", || {
        black_box(count_weights(&mi, Variant::MergedQP).total());
    });
    b.case("full_table_report(both models)", || {
        black_box(table3_report(&py));
        black_box(table3_report(&mi));
    });
    let hw = Hardware::a100_like();
    b.case("bandwidth_model_sweep(6 batches x 2 ctx)", || {
        for batch in [1usize, 4, 16, 64, 256, 1024] {
            for ctx in [512usize, 4096] {
                black_box(predicted_speedup(&mi, Variant::MergedQP, &hw, batch, ctx, 2.0));
            }
        }
    });
    b.finish();
}
