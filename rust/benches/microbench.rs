//! Whole-stack microbenchmarks — the instrument for the EXPERIMENTS.md
//! §Perf pass. Covers every layer the serving hot path touches:
//! GEMM (projection kernels), LU (surgery), attention decode, paged-cache
//! ops, tokenizer, JSON codec, and the scheduler's per-step overhead.

use skipless::config::ModelConfig;
use skipless::coordinator::{CpuEngine, DecodeInput, Engine, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::KvCache;
use skipless::linalg::{inverse, matmul, matmul_transb, matvec};
use skipless::metrics::Metrics;
use skipless::model::ModelWeights;
use skipless::tensor::Mat;
use skipless::tokenizer::Bpe;
use skipless::util::bench::{black_box, Bencher};
use skipless::util::json::Json;
use skipless::util::rng::Xoshiro256;
use std::sync::Arc;

fn main() {
    println!("# microbench — per-layer hot-path instrumentation");
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut b = Bencher::new("microbench");

    // ---- linalg: the projection GEMMs the decode path is made of
    for &n in &[256usize, 512, 1024] {
        let a = Mat::randn(n, n, 0.1, &mut rng);
        let bm = Mat::randn(n, n, 0.1, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        let s = b.case_items(&format!("gemm_{n}x{n}"), Some(flops), || {
            black_box(matmul(&a, &bm));
        });
        let gflops = s.items_per_sec().unwrap_or(0.0) / 1e9;
        eprintln!("    -> {gflops:.2} GFLOP/s");
    }
    // batch-1 decode GEMV (the memory-bound shape the paper reasons about)
    let w640 = Mat::randn(640, 640, 0.1, &mut rng);
    let x640: Vec<f32> = (0..640).map(|i| (i as f32 * 0.01).sin()).collect();
    b.case_items("gemv_640 (batch-1 projection)", Some(2.0 * 640.0 * 640.0), || {
        black_box(matvec(&w640, &x640));
    });
    let q = Mat::randn(1, 64, 0.5, &mut rng);
    let kcache = Mat::randn(256, 64, 0.5, &mut rng);
    b.case("attention_scores_1x256ctx", || {
        black_box(matmul_transb(&q, &kcache));
    });
    let m256 = Mat::randn(256, 256, 0.1, &mut rng);
    b.case("lu_inverse_256 (surgery unit)", || {
        black_box(inverse(&m256).unwrap());
    });

    // ---- paged KV cache ops
    let cfg = ModelConfig::e2e_100m();
    let mut cache = KvCache::new(&cfg, 16, 64 << 20);
    let id = cache.alloc_seq(4).unwrap();
    let krow = vec![0.5f32; cfg.e()];
    for _ in 0..64 {
        for l in 0..cfg.n_layers {
            cache.append(id, l, &krow, &krow).unwrap();
        }
        cache.advance(id).unwrap();
    }
    let (mut kbuf, mut vbuf) = (Vec::new(), Vec::new());
    b.case("kvcache_append_one_layer", || {
        // append+rollback cycle is not possible; measure gather (dominant)
        black_box(cache.gather(id, 0, &mut kbuf, &mut vbuf).unwrap());
    });

    // ---- tokenizer / codec
    let corpus: String = "the quick brown fox jumps over the lazy dog. ".repeat(40);
    let bpe = Bpe::train(&corpus, 512);
    b.case_items("bpe_encode_1k_chars", Some(1000.0), || {
        black_box(bpe.encode(&corpus[..1000]));
    });
    let json_src = r#"{"op":"generate","prompt":[1,2,3,4,5,6,7,8],"max_new_tokens":16,"temperature":0.7,"top_k":40,"top_p":0.95,"seed":42}"#;
    b.case("json_parse_request", || {
        black_box(Json::parse(json_src).unwrap());
    });

    // ---- engine decode step (tiny model → scheduler overhead visible)
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 3);
    let mut eng = CpuEngine::new(w.clone(), 16, 32 << 20);
    let (sid, _) = eng.prefill(&[1, 2, 3]).unwrap();
    b.case("cpu_engine_decode_b1_tiny", || {
        black_box(eng.decode_batch(&[DecodeInput { seq: sid, token: 5 }]).unwrap());
    });

    // ---- full scheduler step (admit + decode + retire) on tiny model
    b.case("scheduler_full_request_tiny(8 new tokens)", || {
        let mut s = Scheduler::new(
            CpuEngine::new(w.clone(), 16, 32 << 20),
            SchedulerCfg::default(),
            Arc::new(Metrics::new()),
        );
        s.submit(Request::greedy(1, vec![1, 2, 3], 8));
        black_box(s.run_to_completion());
    });

    b.finish();

    // ---- scheduler-policy ablation (DESIGN.md §Perf: batching policy) ----
    // 16 requests × 8 tokens; sweep the per-step token budget and the
    // max-running cap; report wall, TTFT p95 and throughput. A bigger
    // budget admits/prefills more aggressively per step, raising
    // throughput but letting prompt work crowd running decodes
    // (TTFT/TPOT interference) — the classic continuous-batching tradeoff.
    eprintln!("\n  scheduler ablation (16 req × 8 tok, tiny-gqa):");
    eprintln!("  budget/step  max_running   wall        ttft p95     tok/s");
    for (budget, max_running) in [(32usize, 2usize), (32, 8), (128, 8), (512, 16)] {
        let metrics = Arc::new(Metrics::new());
        let mut s = Scheduler::new(
            CpuEngine::new(w.clone(), 16, 64 << 20),
            SchedulerCfg {
                max_running,
                token_budget_per_step: budget,
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        for i in 0..16u64 {
            s.submit(Request::greedy(i, vec![(i % 7 + 1) as u32, 2, 3], 8));
        }
        let t0 = std::time::Instant::now();
        let done = s.run_to_completion();
        let wall = t0.elapsed();
        assert_eq!(done.len(), 16);
        let toks: usize = done.iter().map(|r| r.tokens.len()).sum();
        eprintln!(
            "  {:>11}  {:>11}   {:>9}   {:>9}   {:>7.0}",
            budget,
            max_running,
            skipless::util::bench::fmt_dur(wall),
            skipless::util::bench::fmt_dur(metrics.ttft.quantile(0.95)),
            toks as f64 / wall.as_secs_f64()
        );
        println!(
            "{{\"suite\":\"scheduler_ablation\",\"token_budget\":{budget},\"max_running\":{max_running},\"wall_us\":{:.1},\"ttft_p95_us\":{},\"tok_per_s\":{:.1}}}",
            wall.as_secs_f64() * 1e6,
            metrics.ttft.quantile(0.95).as_micros(),
            toks as f64 / wall.as_secs_f64()
        );
    }
}
