//! Whole-stack microbenchmarks — the instrument for the EXPERIMENTS.md
//! §Perf pass. Covers every layer the serving hot path touches:
//! GEMM (projection kernels), LU (surgery), attention decode, paged-cache
//! ops, tokenizer, JSON codec, and the scheduler's per-step overhead.

use skipless::config::ModelConfig;
use skipless::coordinator::{CpuEngine, DecodeInput, Engine, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::{BlockView, KvCache};
use skipless::linalg::gemm::{matmul_into_with, matmul_transb_with, matvec_with};
use skipless::linalg::qgemm::qmatmul_with;
use skipless::linalg::simd::{self, SimdLevel};
use skipless::linalg::{inverse, matmul, matmul_transb, matvec};
use skipless::metrics::Metrics;
use skipless::model::attention::HeadLayout;
use skipless::model::paged_attn::{attend_gathered, attend_paged, KvSegment};
use skipless::model::ModelWeights;
use skipless::tensor::{Mat, QMat};
use skipless::tokenizer::Bpe;
use skipless::util::bench::{black_box, Bencher};
use skipless::util::json::Json;
use skipless::util::rng::Xoshiro256;
use std::sync::Arc;

/// One before/after row for `BENCH_kernels.json`.
struct KernelRow {
    kernel: &'static str,
    shape: String,
    scalar_us: f64,
    dispatched_us: f64,
    bit_identical: bool,
}

impl KernelRow {
    fn speedup(&self) -> f64 {
        self.scalar_us / self.dispatched_us
    }
}

fn main() {
    println!("# microbench — per-layer hot-path instrumentation");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut b = Bencher::new("microbench");

    // ---- linalg: the projection GEMMs the decode path is made of
    for &n in &[256usize, 512, 1024] {
        let a = Mat::randn(n, n, 0.1, &mut rng);
        let bm = Mat::randn(n, n, 0.1, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        let s = b.case_items(&format!("gemm_{n}x{n}"), Some(flops), || {
            black_box(matmul(&a, &bm));
        });
        let gflops = s.items_per_sec().unwrap_or(0.0) / 1e9;
        eprintln!("    -> {gflops:.2} GFLOP/s");
    }
    // batch-1 decode GEMV (the memory-bound shape the paper reasons about)
    let w640 = Mat::randn(640, 640, 0.1, &mut rng);
    let x640: Vec<f32> = (0..640).map(|i| (i as f32 * 0.01).sin()).collect();
    b.case_items("gemv_640 (batch-1 projection)", Some(2.0 * 640.0 * 640.0), || {
        black_box(matvec(&w640, &x640));
    });
    let q = Mat::randn(1, 64, 0.5, &mut rng);
    let kcache = Mat::randn(256, 64, 0.5, &mut rng);
    b.case("attention_scores_1x256ctx", || {
        black_box(matmul_transb(&q, &kcache));
    });
    let m256 = Mat::randn(256, 256, 0.1, &mut rng);
    b.case("lu_inverse_256 (surgery unit)", || {
        black_box(inverse(&m256).unwrap());
    });

    // ---- paged KV cache ops
    let cfg = ModelConfig::e2e_100m();
    let mut cache = KvCache::new(&cfg, 16, 64 << 20);
    let id = cache.alloc_seq(4).unwrap();
    let krow = vec![0.5f32; cfg.e()];
    for _ in 0..64 {
        for l in 0..cfg.n_layers {
            cache.append(id, l, &krow, &krow).unwrap();
        }
        cache.advance(id).unwrap();
    }
    let (mut kbuf, mut vbuf) = (Vec::new(), Vec::new());
    b.case("kvcache_append_one_layer", || {
        // append+rollback cycle is not possible; measure gather (dominant)
        black_box(cache.gather(id, 0, &mut kbuf, &mut vbuf).unwrap());
    });

    // ---- kernel dispatch before/after (ISSUE 6): the forced-scalar oracle
    // vs whatever simd::level() picked, at serving shapes, with byte
    // identity asserted on every pair before timing. Rows land in
    // BENCH_kernels.json; in full mode on a SIMD host the qmatmul and
    // matmul_transb speedups are asserted (>=2x / >=1.5x).
    let lvl = simd::level();
    let mut krows: Vec<KernelRow> = Vec::new();
    eprintln!("  kernel dispatch: {} (scalar-vs-dispatched rows follow)", simd::level_name());

    // chunked-prefill projection GEMM: (64,640) x (640,640)
    {
        let (m, n, k) = (64usize, 640usize, 640usize);
        let a = Mat::randn(m, k, 0.1, &mut rng);
        let w = Mat::randn(k, n, 0.1, &mut rng);
        let mut out_s = Mat::zeros(m, n);
        let mut out_d = Mat::zeros(m, n);
        matmul_into_with(SimdLevel::Scalar, &a, &w, &mut out_s);
        matmul_into_with(lvl, &a, &w, &mut out_d);
        let bit = out_s.as_slice().iter().zip(out_d.as_slice()).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(bit, "matmul scalar vs dispatched diverged");
        let flops = 2.0 * (m * n * k) as f64;
        let s = b.case_items("matmul_64x640x640[scalar]", Some(flops), || {
            matmul_into_with(SimdLevel::Scalar, &a, &w, &mut out_s);
            black_box(&out_s);
        }).clone();
        let d = b.case_items("matmul_64x640x640[dispatched]", Some(flops), || {
            matmul_into_with(lvl, &a, &w, &mut out_d);
            black_box(&out_d);
        }).clone();
        krows.push(KernelRow {
            kernel: "matmul",
            shape: format!("{m}x{k}x{n}"),
            scalar_us: s.median.as_secs_f64() * 1e6,
            dispatched_us: d.median.as_secs_f64() * 1e6,
            bit_identical: bit,
        });
    }

    // attention-score GEMM at a serving shape: (256,64) @ (256,64)^T
    {
        let (m, n, k) = (256usize, 256usize, 64usize);
        let a = Mat::randn(m, k, 0.5, &mut rng);
        let bt = Mat::randn(n, k, 0.5, &mut rng);
        let out_s = matmul_transb_with(SimdLevel::Scalar, &a, &bt);
        let out_d = matmul_transb_with(lvl, &a, &bt);
        let bit = out_s.as_slice().iter().zip(out_d.as_slice()).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(bit, "matmul_transb scalar vs dispatched diverged");
        let flops = 2.0 * (m * n * k) as f64;
        let s = b.case_items("matmul_transb_256x64[scalar]", Some(flops), || {
            black_box(matmul_transb_with(SimdLevel::Scalar, &a, &bt));
        }).clone();
        let d = b.case_items("matmul_transb_256x64[dispatched]", Some(flops), || {
            black_box(matmul_transb_with(lvl, &a, &bt));
        }).clone();
        krows.push(KernelRow {
            kernel: "matmul_transb",
            shape: format!("{m}x{k}@{n}x{k}T"),
            scalar_us: s.median.as_secs_f64() * 1e6,
            dispatched_us: d.median.as_secs_f64() * 1e6,
            bit_identical: bit,
        });
    }

    // batch-1 decode GEMV: (640,640) x 640
    {
        let w = Mat::randn(640, 640, 0.1, &mut rng);
        let x: Vec<f32> = (0..640).map(|i| (i as f32 * 0.013).sin()).collect();
        let out_s = matvec_with(SimdLevel::Scalar, &w, &x);
        let out_d = matvec_with(lvl, &w, &x);
        let bit = out_s.iter().zip(&out_d).all(|(a, c)| a.to_bits() == c.to_bits());
        assert!(bit, "matvec scalar vs dispatched diverged");
        let flops = 2.0 * 640.0 * 640.0;
        let s = b.case_items("matvec_640[scalar]", Some(flops), || {
            black_box(matvec_with(SimdLevel::Scalar, &w, &x));
        }).clone();
        let d = b.case_items("matvec_640[dispatched]", Some(flops), || {
            black_box(matvec_with(lvl, &w, &x));
        }).clone();
        krows.push(KernelRow {
            kernel: "matvec",
            shape: "640x640".into(),
            scalar_us: s.median.as_secs_f64() * 1e6,
            dispatched_us: d.median.as_secs_f64() * 1e6,
            bit_identical: bit,
        });
    }

    // INT8 projection qGEMM at the serving decode shape: (4,640) x (640,640)
    {
        let (m, n, k) = (4usize, 640usize, 640usize);
        let x = Mat::randn(m, k, 0.5, &mut rng);
        let w = QMat::quantize_rows(&Mat::randn(n, k, 0.05, &mut rng));
        let out_s = qmatmul_with(SimdLevel::Scalar, &x, &w);
        let out_d = qmatmul_with(lvl, &x, &w);
        let bit = out_s.as_slice().iter().zip(out_d.as_slice()).all(|(a, c)| a.to_bits() == c.to_bits());
        assert!(bit, "qmatmul scalar vs dispatched diverged");
        let flops = 2.0 * (m * n * k) as f64;
        let s = b.case_items("qmatmul_4x640x640[scalar]", Some(flops), || {
            black_box(qmatmul_with(SimdLevel::Scalar, &x, &w));
        }).clone();
        let d = b.case_items("qmatmul_4x640x640[dispatched]", Some(flops), || {
            black_box(qmatmul_with(lvl, &x, &w));
        }).clone();
        krows.push(KernelRow {
            kernel: "qmatmul",
            shape: format!("{m}x{k}x{n}"),
            scalar_us: s.median.as_secs_f64() * 1e6,
            dispatched_us: d.median.as_secs_f64() * 1e6,
            bit_identical: bit,
        });
    }

    // fused paged-attention decode over the 64-token history built above:
    // scalar oracle (attend_gathered on pre-gathered rows, zero copy cost
    // in the timed region) vs the dispatched zero-copy kernel.
    {
        let layout = HeadLayout {
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim(),
        };
        let t = cache.gather(id, 0, &mut kbuf, &mut vbuf).unwrap();
        let views: Vec<BlockView> = cache.seq_block_views(id, 0).unwrap().collect();
        let tails = [KvSegment::empty(), KvSegment::empty()];
        let q_attn = Mat::randn(1, layout.d(), 0.5, &mut rng);
        let mut out_s = vec![0.0f32; layout.d()];
        let mut out_d = vec![0.0f32; layout.d()];
        let mut scores = Vec::new();
        attend_gathered(layout, q_attn.row(0), &kbuf, &vbuf, t, &mut out_s);
        attend_paged(layout, q_attn.row(0), &views, &tails, t, &mut scores, &mut out_d);
        let bit = out_s.iter().zip(&out_d).all(|(a, c)| a.to_bits() == c.to_bits());
        assert!(bit, "attend scalar oracle vs dispatched diverged");
        let s = b.case(&format!("attend_1x{t}ctx[scalar]"), || {
            attend_gathered(layout, q_attn.row(0), &kbuf, &vbuf, t, &mut out_s);
            black_box(&out_s);
        }).clone();
        let d = b.case(&format!("attend_1x{t}ctx[dispatched]"), || {
            attend_paged(layout, q_attn.row(0), &views, &tails, t, &mut scores, &mut out_d);
            black_box(&out_d);
        }).clone();
        krows.push(KernelRow {
            kernel: "attend_paged",
            shape: format!("1x{t}ctx e={}", cfg.e()),
            scalar_us: s.median.as_secs_f64() * 1e6,
            dispatched_us: d.median.as_secs_f64() * 1e6,
            bit_identical: bit,
        });
    }

    // ---- tokenizer / codec
    let corpus: String = "the quick brown fox jumps over the lazy dog. ".repeat(40);
    let bpe = Bpe::train(&corpus, 512);
    b.case_items("bpe_encode_1k_chars", Some(1000.0), || {
        black_box(bpe.encode(&corpus[..1000]));
    });
    let json_src = r#"{"op":"generate","prompt":[1,2,3,4,5,6,7,8],"max_new_tokens":16,"temperature":0.7,"top_k":40,"top_p":0.95,"seed":42}"#;
    b.case("json_parse_request", || {
        black_box(Json::parse(json_src).unwrap());
    });

    // ---- engine decode step (tiny model → scheduler overhead visible)
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 3);
    let mut eng = CpuEngine::new(w.clone(), 16, 32 << 20);
    let (sid, _) = eng.prefill(&[1, 2, 3]).unwrap();
    b.case("cpu_engine_decode_b1_tiny", || {
        black_box(eng.decode_batch(&[DecodeInput { seq: sid, token: 5 }]).unwrap());
    });

    // ---- full scheduler step (admit + decode + retire) on tiny model
    b.case("scheduler_full_request_tiny(8 new tokens)", || {
        let mut s = Scheduler::new(
            CpuEngine::new(w.clone(), 16, 32 << 20),
            SchedulerCfg::default(),
            Arc::new(Metrics::new()),
        );
        s.submit(Request::greedy(1, vec![1, 2, 3], 8));
        black_box(s.run_to_completion());
    });

    b.finish();

    // ---- BENCH_kernels.json: before/after dispatch rows ----
    eprintln!("\n  kernel before/after ({}):", simd::level_name());
    for r in &krows {
        eprintln!(
            "  {:<14} {:<18} scalar {:>9.1}µs  dispatched {:>9.1}µs  {:>5.2}x  bits={}",
            r.kernel, r.shape, r.scalar_us, r.dispatched_us, r.speedup(), r.bit_identical
        );
        assert!(r.bit_identical, "{}: SIMD output not byte-equal to scalar", r.kernel);
    }
    let rows_json: Vec<String> = krows
        .iter()
        .map(|r| {
            format!(
                "    {{\"kernel\": \"{}\", \"shape\": \"{}\", \"scalar_us\": {:.3}, \
                 \"dispatched_us\": {:.3}, \"speedup\": {:.3}, \"bit_identical\": {}}}",
                r.kernel, r.shape, r.scalar_us, r.dispatched_us, r.speedup(), r.bit_identical
            )
        })
        .collect();
    let kjson = format!(
        "{{\n  \"suite\": \"kernels\",\n  \"dispatch\": \"{}\",\n  \"quick\": {},\n  \
         \"cases\": [\n{}\n  ]\n}}\n",
        simd::level_name(),
        quick,
        rows_json.join(",\n")
    );
    std::fs::write("BENCH_kernels.json", &kjson).expect("write BENCH_kernels.json");
    eprintln!("  wrote BENCH_kernels.json");
    // Speedup gates (full mode on a SIMD host only: quick mode's handful of
    // reps is too noisy to gate on, and forced-scalar runs have no "after").
    if !quick && lvl != SimdLevel::Scalar {
        let get = |k: &str| krows.iter().find(|r| r.kernel == k).unwrap().speedup();
        let (sq, st) = (get("qmatmul"), get("matmul_transb"));
        assert!(sq >= 2.0, "qmatmul speedup {sq:.2}x < 2.0x at serving shape");
        assert!(st >= 1.5, "matmul_transb speedup {st:.2}x < 1.5x at serving shape");
    }

    // ---- scheduler-policy ablation (DESIGN.md §Perf: batching policy) ----
    // 16 requests × 8 tokens; sweep the per-step token budget and the
    // max-running cap; report wall, TTFT p95 and throughput. A bigger
    // budget admits/prefills more aggressively per step, raising
    // throughput but letting prompt work crowd running decodes
    // (TTFT/TPOT interference) — the classic continuous-batching tradeoff.
    eprintln!("\n  scheduler ablation (16 req × 8 tok, tiny-gqa):");
    eprintln!("  budget/step  max_running   wall        ttft p95     tok/s");
    for (budget, max_running) in [(32usize, 2usize), (32, 8), (128, 8), (512, 16)] {
        let metrics = Arc::new(Metrics::new());
        let mut s = Scheduler::new(
            CpuEngine::new(w.clone(), 16, 64 << 20),
            SchedulerCfg {
                max_running,
                token_budget_per_step: budget,
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        for i in 0..16u64 {
            s.submit(Request::greedy(i, vec![(i % 7 + 1) as u32, 2, 3], 8));
        }
        let t0 = std::time::Instant::now();
        let done = s.run_to_completion();
        let wall = t0.elapsed();
        assert_eq!(done.len(), 16);
        let toks: usize = done.iter().map(|r| r.tokens.len()).sum();
        eprintln!(
            "  {:>11}  {:>11}   {:>9}   {:>9}   {:>7.0}",
            budget,
            max_running,
            skipless::util::bench::fmt_dur(wall),
            skipless::util::bench::fmt_dur(metrics.ttft.quantile(0.95)),
            toks as f64 / wall.as_secs_f64()
        );
        println!(
            "{{\"suite\":\"scheduler_ablation\",\"token_budget\":{budget},\"max_running\":{max_running},\"wall_us\":{:.1},\"ttft_p95_us\":{},\"tok_per_s\":{:.1}}}",
            wall.as_secs_f64() * 1e6,
            metrics.ttft.quantile(0.95).as_micros(),
            toks as f64 / wall.as_secs_f64()
        );
    }
}
