//! Allocation-churn bench: the allocating engine API (`step_batch` /
//! `verify_batch`, fresh output vectors every step) versus the step-arena
//! API (`step_batch_into` / `verify_batch_into`, caller-owned
//! capacity-reusing outputs), with a counting global allocator attributing
//! every heap event to its step.
//!
//! Reported per mode — {batch-1 decode, batch-8 decode, speculative
//! verify, decode after chunked prefill} — are allocs/step and bytes/step
//! for both APIs and the decode TPOT (time per output token = wall time of
//! one fused step) p50/p99. The arena API must measure **exactly zero**
//! allocations per steady-state step in every mode, quick or full — that
//! is the same contract `tests/alloc_regression.rs` gates, re-checked here
//! under bench-length runs (hundreds of steps, not four). The batch-8
//! decode cell additionally asserts a ≥1.1× median-TPOT advantage for the
//! arena API in full mode (quick CI runs skip the timing bar: timings on
//! loaded runners are noise, allocation counts are not).
//!
//! The model is deliberately allocation-heavy relative to compute (small
//! dim, large vocab): what this bench isolates is output-buffer churn, and
//! a wide logits row makes every fresh `Vec<f32>` expensive. Threads are
//! forced to 1 (`SKIPLESS_THREADS=1`) so both APIs take the same serial
//! code path and per-step timings are not scheduler noise. Emits
//! `BENCH_alloc.json` (schema in EXPERIMENTS.md).

use skipless::config::{AttentionKind, BlockLayout, FfnKind, ModelConfig};
use skipless::coordinator::{
    ChunkInput, CpuEngine, DecodeInput, Engine, StepOut, VerifyInput, VerifyOut,
};
use skipless::model::ModelWeights;
use skipless::sampler::argmax;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::{Duration, Instant};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(l.size() as u64, Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(l.size() as u64, Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// One block per sequence (block size = max_seq_len), so a bench-length
/// decode run never crosses a block boundary: block grants are admission
/// work, and letting one leak into the measured window would misattribute
/// it to the steady state.
fn bench_config(quick: bool) -> ModelConfig {
    ModelConfig {
        name: "alloc-bench".into(),
        dim: 64,
        n_layers: 2,
        n_heads: 8,
        n_kv_heads: 2,
        hidden_dim: 112,
        vocab_size: if quick { 512 } else { 2048 },
        max_seq_len: if quick { 256 } else { 1024 },
        attention: AttentionKind::Gqa,
        layout: BlockLayout::Serial,
        ffn: FfnKind::SwiGlu,
        tied_embeddings: false,
    }
}

const BUDGET: usize = 64 << 20;
const WARMUP: usize = 4;

struct CellStats {
    allocs_per_step: f64,
    bytes_per_step: f64,
    p50_us: f64,
    p99_us: f64,
    max_step_allocs: u64,
}

/// Run `n` steps of `f`, attributing allocator and wall-clock deltas to
/// each. The duration buffer is pre-sized and counters are read before the
/// push, so the harness itself never contaminates a window.
fn measure_steps(n: usize, mut f: impl FnMut()) -> CellStats {
    let mut durs: Vec<Duration> = Vec::with_capacity(n);
    let (mut allocs, mut bytes, mut max_step) = (0u64, 0u64, 0u64);
    for _ in 0..n {
        let a0 = ALLOCS.load(Relaxed);
        let b0 = ALLOC_BYTES.load(Relaxed);
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        let da = ALLOCS.load(Relaxed) - a0;
        let db = ALLOC_BYTES.load(Relaxed) - b0;
        allocs += da;
        bytes += db;
        max_step = max_step.max(da);
        durs.push(dt);
    }
    durs.sort_unstable();
    let pct = |p: f64| durs[((durs.len() as f64 * p) as usize).min(durs.len() - 1)];
    CellStats {
        allocs_per_step: allocs as f64 / n as f64,
        bytes_per_step: bytes as f64 / n as f64,
        p50_us: pct(0.50).as_secs_f64() * 1e6,
        p99_us: pct(0.99).as_secs_f64() * 1e6,
        max_step_allocs: max_step,
    }
}

fn report(mode: &str, api: &str, s: &CellStats) {
    eprintln!(
        "  {:<16} {:<6} {:>9.1} allocs/step {:>12.0} B/step   TPOT p50 {:>9.2}µs  p99 {:>9.2}µs",
        mode, api, s.allocs_per_step, s.bytes_per_step, s.p50_us, s.p99_us
    );
}

fn json_mode(mode: &str, before: &CellStats, after: &CellStats) -> String {
    format!(
        "  \"{mode}\": {{\n    \"allocs_per_step_before\": {:.2},\n    \"bytes_per_step_before\": {:.0},\n    \"allocs_per_step_after\": {:.2},\n    \"bytes_per_step_after\": {:.0},\n    \"tpot_p50_us_before\": {:.3},\n    \"tpot_p99_us_before\": {:.3},\n    \"tpot_p50_us_after\": {:.3},\n    \"tpot_p99_us_after\": {:.3},\n    \"zero_alloc\": {}\n  }}",
        before.allocs_per_step,
        before.bytes_per_step,
        after.allocs_per_step,
        after.bytes_per_step,
        before.p50_us,
        before.p99_us,
        after.p50_us,
        after.p99_us,
        after.max_step_allocs == 0,
    )
}

/// Plain-decode cell at the given batch size: `before` drives the
/// allocating `step_batch`, `after` drives `step_batch_into` on a warmed
/// arena. Both engines walk the same greedy token streams (bit-identity of
/// the two APIs is pinned by the test suites; here it keeps the work equal).
fn decode_cell(w: &ModelWeights, cfg: &ModelConfig, batch: usize, steps: usize) -> (CellStats, CellStats) {
    let block = cfg.max_seq_len;
    let vocab = cfg.vocab_size as u32;
    let mut before = CpuEngine::new(w.clone(), block, BUDGET);
    let mut after = CpuEngine::new(w.clone(), block, BUDGET);
    after.plan_alloc(batch, 0);
    let mut seqs_b = Vec::new();
    let mut seqs_a = Vec::new();
    let mut toks = Vec::new();
    for i in 0..batch {
        let prompt: Vec<u32> = (0..9).map(|j| (i as u32 * 37 + j * 13 + 5) % vocab).collect();
        let (sb, lb) = before.prefill(&prompt).unwrap();
        let (sa, _) = after.prefill(&prompt).unwrap();
        seqs_b.push(sb);
        seqs_a.push(sa);
        toks.push(argmax(&lb));
    }
    let mut out = StepOut::default();
    let mut inputs: Vec<DecodeInput> = Vec::with_capacity(batch);
    for _ in 0..WARMUP {
        inputs.clear();
        inputs.extend(seqs_a.iter().zip(&toks).map(|(&seq, &token)| DecodeInput { seq, token }));
        after.step_batch_into(&inputs, &[], &mut out).unwrap();
        before
            .step_batch(
                &seqs_b
                    .iter()
                    .zip(&toks)
                    .map(|(&seq, &token)| DecodeInput { seq, token })
                    .collect::<Vec<_>>(),
                &[],
            )
            .unwrap();
        for (i, t) in toks.iter_mut().enumerate() {
            *t = argmax(out.decode_logits.row(i));
        }
    }
    let mut toks_b = toks.clone();
    let sa = measure_steps(steps, || {
        inputs.clear();
        inputs.extend(toks.iter().zip(&seqs_a).map(|(&token, &seq)| DecodeInput { seq, token }));
        after.step_batch_into(&inputs, &[], &mut out).unwrap();
        for (i, t) in toks.iter_mut().enumerate() {
            *t = argmax(out.decode_logits.row(i));
        }
    });
    let mut inputs_b: Vec<DecodeInput> = Vec::with_capacity(batch);
    let sb = measure_steps(steps, || {
        inputs_b.clear();
        inputs_b
            .extend(toks_b.iter().zip(&seqs_b).map(|(&token, &seq)| DecodeInput { seq, token }));
        let r = before.step_batch(&inputs_b, &[]).unwrap();
        for (t, row) in toks_b.iter_mut().zip(&r.decode_logits) {
            *t = argmax(row);
        }
    });
    (sb, sa)
}

/// Speculative-verify cell: a fixed 4-token draft verified and rolled back
/// each round (rollback runs outside the timed/counted window on both
/// sides — block frees are not steady-state work).
fn verify_cell(w: &ModelWeights, cfg: &ModelConfig, rounds: usize) -> (CellStats, CellStats) {
    let block = cfg.max_seq_len;
    let mut before = CpuEngine::new(w.clone(), block, BUDGET);
    let mut after = CpuEngine::new(w.clone(), block, BUDGET);
    after.plan_alloc(2, 4);
    let prompt = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
    let (sb, _) = before.prefill(&prompt).unwrap();
    let (sa, _) = after.prefill(&prompt).unwrap();
    let inputs_b = [VerifyInput { seq: sb, tokens: vec![7, 8, 9, 10] }];
    let inputs_a = [VerifyInput { seq: sa, tokens: vec![7, 8, 9, 10] }];
    let mut out = VerifyOut::default();
    for _ in 0..WARMUP {
        after.verify_batch_into(&inputs_a, &mut out).unwrap();
        after.truncate(sa, prompt.len()).unwrap();
        before.verify_batch(&inputs_b).unwrap();
        before.truncate(sb, prompt.len()).unwrap();
    }
    // positions must roll back between rounds, so truncate rides inside the
    // step on both sides symmetrically — it is allocation-free here (one
    // block per sequence, so no boundary is ever crossed)
    let stats_a = measure_steps(rounds, || {
        after.verify_batch_into(&inputs_a, &mut out).unwrap();
        after.truncate(sa, prompt.len()).unwrap();
    });
    let stats_b = measure_steps(rounds, || {
        before.verify_batch(&inputs_b).unwrap();
        before.truncate(sb, prompt.len()).unwrap();
    });
    (stats_b, stats_a)
}

/// Chunked-admission cell: feed the prompt in uneven chunks (allocating
/// admission work on both sides), then measure the pure decode steps that
/// follow.
fn chunked_cell(w: &ModelWeights, cfg: &ModelConfig, steps: usize) -> (CellStats, CellStats) {
    let block = cfg.max_seq_len;
    let vocab = cfg.vocab_size as u32;
    let mut before = CpuEngine::new(w.clone(), block, BUDGET);
    let mut after = CpuEngine::new(w.clone(), block, BUDGET);
    after.plan_alloc(8, 0);
    let prompt: Vec<u32> = (0..11).map(|j| (j * 7 + 2) % vocab).collect();
    let mut admit = |e: &mut CpuEngine| {
        let (seq, _) = e.prefill_begin(&prompt).unwrap();
        let mut last = None;
        for chunk in [&prompt[0..3], &prompt[3..8], &prompt[8..11]] {
            let out = e.step_batch(&[], &[ChunkInput { seq, tokens: chunk.to_vec() }]).unwrap();
            if let Some(row) = out.chunk_logits.into_iter().next().flatten() {
                last = Some(argmax(&row));
            }
        }
        (seq, last.expect("prompt complete"))
    };
    let (sb, mut tb) = admit(&mut before);
    let (sa, mut ta) = admit(&mut after);
    let mut out = StepOut::default();
    for _ in 0..WARMUP {
        after.step_batch_into(&[DecodeInput { seq: sa, token: ta }], &[], &mut out).unwrap();
        ta = argmax(out.decode_logits.row(0));
    }
    let stats_a = measure_steps(steps, || {
        after.step_batch_into(&[DecodeInput { seq: sa, token: ta }], &[], &mut out).unwrap();
        ta = argmax(out.decode_logits.row(0));
    });
    let stats_b = measure_steps(steps, || {
        let r = before.step_batch(&[DecodeInput { seq: sb, token: tb }], &[]).unwrap();
        tb = argmax(&r.decode_logits[0]);
    });
    (stats_b, stats_a)
}

fn main() {
    println!("# alloc_churn — allocating engine API vs zero-allocation step arena");
    std::env::set_var("SKIPLESS_THREADS", "1");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = bench_config(quick);
    let (steps, rounds) = if quick { (60, 40) } else { (300, 200) };
    eprintln!("  initializing {} (vocab {}, {} measured steps)...", cfg.name, cfg.vocab_size, steps);
    let w = ModelWeights::init_vanilla(&cfg, 4096);

    let (b1_before, b1_after) = decode_cell(&w, &cfg, 1, steps);
    report("decode_b1", "before", &b1_before);
    report("decode_b1", "after", &b1_after);
    let (b8_before, b8_after) = decode_cell(&w, &cfg, 8, steps);
    report("decode_b8", "before", &b8_before);
    report("decode_b8", "after", &b8_after);
    let (sv_before, sv_after) = verify_cell(&w, &cfg, rounds);
    report("spec_verify", "before", &sv_before);
    report("spec_verify", "after", &sv_after);
    let (ck_before, ck_after) = chunked_cell(&w, &cfg, steps);
    report("chunked_decode", "before", &ck_before);
    report("chunked_decode", "after", &ck_after);

    // the contract, re-checked at bench length in EVERY mode: not one
    // allocation in any measured arena-API step
    for (mode, s) in [
        ("decode_b1", &b1_after),
        ("decode_b8", &b8_after),
        ("spec_verify", &sv_after),
        ("chunked_decode", &ck_after),
    ] {
        assert_eq!(
            s.max_step_allocs, 0,
            "{mode}: arena API allocated in steady state ({:.2}/step)",
            s.allocs_per_step
        );
    }
    // and the allocating API must actually churn, or `before` stopped
    // meaning anything
    assert!(b8_before.allocs_per_step >= 1.0, "allocating API reported no allocations");

    let tpot_ratio = b8_before.p50_us / b8_after.p50_us.max(1e-9);
    eprintln!("  decode_b8: median-TPOT ratio before/after = {tpot_ratio:.3}x");
    println!(
        "{{\"suite\":\"alloc_churn\",\"case\":\"decode_b8\",\"allocs_per_step_before\":{:.2},\"allocs_per_step_after\":{:.2},\"tpot_ratio\":{tpot_ratio:.4}}}",
        b8_before.allocs_per_step, b8_after.allocs_per_step,
    );
    if !quick {
        assert!(
            tpot_ratio >= 1.1,
            "batch-8 decode: arena API is only {tpot_ratio:.3}x faster (bar: 1.1x)"
        );
    }

    let json = format!(
        "{{\n  \"suite\": \"alloc_churn\",\n  \"model\": \"{}\",\n  \"vocab\": {},\n  \"measured_steps\": {steps},\n  \"threads\": 1,\n  \"decode_b8_tpot_ratio\": {tpot_ratio:.4},\n{},\n{},\n{},\n{}\n}}\n",
        cfg.name,
        cfg.vocab_size,
        json_mode("decode_b1", &b1_before, &b1_after),
        json_mode("decode_b8", &b8_before, &b8_after),
        json_mode("spec_verify", &sv_before, &sv_after),
        json_mode("chunked_decode", &ck_before, &ck_after),
    );
    std::fs::write("BENCH_alloc.json", &json).expect("write BENCH_alloc.json");
    eprintln!("  wrote BENCH_alloc.json");
}
