//! INT8 serving bench: decode throughput and resident bytes, f32 vs int8
//! weights (and f32 vs u8 KV blocks on the cache side).
//!
//! Batch-1 decode on CPU is weight-streaming-bound — the regime the paper's
//! §3 model assumes — so shrinking the streamed bytes 4x is the whole
//! game. Emits `BENCH_quant.json` (schema in EXPERIMENTS.md) plus the
//! usual JSON result lines on stdout. `SKIPLESS_BENCH_QUICK=1` shrinks the
//! model and token counts for CI.

use skipless::config::{AttentionKind, BlockLayout, FfnKind, ModelConfig};
use skipless::coordinator::{CpuEngine, DecodeInput, Engine};
use skipless::kvcache::CacheOpts;
use skipless::model::{quantize, ModelWeights};
use skipless::util::bench::fmt_dur;
use std::time::{Duration, Instant};

/// Mid-size GQA model: big enough that decode is genuinely bound by
/// streaming the block weights (embedding is a realistically small
/// fraction, unlike the tiny presets), small enough to init in seconds.
fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "quant-bench-85m".into(),
        dim: 384,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 2,
        hidden_dim: 1536,
        vocab_size: 1024,
        max_seq_len: 512,
        attention: AttentionKind::Gqa,
        layout: BlockLayout::Serial,
        ffn: FfnKind::Mlp,
        tied_embeddings: false,
    }
}

struct DecodeRun {
    tok_per_s: f64,
    wall: Duration,
    tokens: usize,
}

/// Prefill `batch` sequences, then decode `steps` tokens each through
/// `decode_batch`, timing only the decode loop.
fn run_decode(mut eng: CpuEngine, batch: usize, steps: usize) -> DecodeRun {
    let vocab = eng.cfg().vocab_size as u32;
    let ids: Vec<_> = (0..batch)
        .map(|i| {
            let prompt = [(i as u32 * 31 + 1) % vocab, 2, 3];
            eng.prefill(&prompt).unwrap().0
        })
        .collect();
    let mut inputs: Vec<DecodeInput> = ids
        .iter()
        .enumerate()
        .map(|(i, &seq)| DecodeInput {
            seq,
            token: (i as u32 * 7 + 5) % vocab,
        })
        .collect();
    let t0 = Instant::now();
    for _step in 0..steps {
        let logits = eng.decode_batch(&inputs).unwrap();
        // feed the argmax back so the run is data-dependent end to end
        for (inp, row) in inputs.iter_mut().zip(&logits) {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            inp.token = best as u32;
        }
    }
    let wall = t0.elapsed();
    let tokens = batch * steps;
    DecodeRun {
        tok_per_s: tokens as f64 / wall.as_secs_f64(),
        wall,
        tokens,
    }
}

fn main() {
    println!("# quant_throughput — INT8 weights + u8 KV blocks vs f32");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = if quick { ModelConfig::tiny_gqa() } else { bench_config() };
    let steps = if quick { 16 } else { 64 };

    eprintln!("  initializing {} (this includes calibration)...", cfg.name);
    let w = ModelWeights::init_vanilla(&cfg, 2026);
    let q = quantize(&w);
    let f32_bytes = w.resident_bytes();
    let int8_bytes = q.resident_bytes();
    let weight_ratio = f32_bytes as f64 / int8_bytes as f64;
    eprintln!(
        "  weights: {:.1} MiB f32 → {:.1} MiB int8 ({:.2}x smaller)",
        f32_bytes as f64 / (1 << 20) as f64,
        int8_bytes as f64 / (1 << 20) as f64,
        weight_ratio
    );
    // the acceptance bar: ≥ 3x resident reduction on a realistically-
    // proportioned model (the f32 embedding is the only thing not shrunk)
    if !quick {
        assert!(weight_ratio >= 3.0, "resident reduction only {weight_ratio:.2}x");
    }

    // -- KV pool capacity at equal budget ------------------------------
    let budget = 64 << 20;
    let kv_f32 = CpuEngine::new(w.clone(), 16, budget).cache().sizing();
    let kv_u8 = CpuEngine::with_cache_opts(
        w.clone(),
        16,
        budget,
        CacheOpts {
            quantized: true,
            ..Default::default()
        },
    )
    .cache()
    .sizing();
    let kv_ratio = kv_u8.tokens_capacity as f64 / kv_f32.tokens_capacity as f64;
    eprintln!(
        "  kv pool @ {} MiB: {} tokens f32 ({} B/tok) → {} tokens u8 ({} B/tok) ({:.2}x)",
        budget >> 20,
        kv_f32.tokens_capacity,
        kv_f32.bytes_per_token,
        kv_u8.tokens_capacity,
        kv_u8.bytes_per_token,
        kv_ratio
    );

    // -- decode throughput ----------------------------------------------
    let mut rows = Vec::new();
    for &batch in &[1usize, 8] {
        let rf = run_decode(CpuEngine::new(w.clone(), 16, budget), batch, steps);
        let rq = run_decode(
            CpuEngine::with_cache_opts(
                q.clone(),
                16,
                budget,
                CacheOpts {
                    quantized: true,
                    ..Default::default()
                },
            ),
            batch,
            steps,
        );
        let speedup = rq.tok_per_s / rf.tok_per_s;
        eprintln!(
            "  batch {batch}: f32 {:>8.1} tok/s ({})   int8 {:>8.1} tok/s ({})   {:.2}x",
            rf.tok_per_s,
            fmt_dur(rf.wall),
            rq.tok_per_s,
            fmt_dur(rq.wall),
            speedup
        );
        println!(
            "{{\"suite\":\"quant_throughput\",\"case\":\"decode_b{batch}\",\"tokens\":{},\"f32_tok_per_s\":{:.1},\"int8_tok_per_s\":{:.1},\"speedup_x\":{speedup:.4}}}",
            rf.tokens, rf.tok_per_s, rq.tok_per_s,
        );
        // Exact speedup is machine-dependent (see EXPERIMENTS.md), but a
        // collapse below half of f32 means the i8 kernel lost its
        // vectorization — fail the full-mode run rather than record it.
        if !quick {
            assert!(
                speedup >= 0.5,
                "catastrophic int8 decode regression at batch {batch}: {speedup:.2}x"
            );
        }
        rows.push((batch, rf.tok_per_s, rq.tok_per_s, speedup));
    }

    // -- machine-readable artifact -------------------------------------
    let decode_json: Vec<String> = rows
        .iter()
        .map(|(b, f, q, s)| {
            format!(
                "    {{\"batch\": {b}, \"f32_tok_per_s\": {f:.1}, \"int8_tok_per_s\": {q:.1}, \"speedup_x\": {s:.4}}}"
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"suite\": \"quant_throughput\",\n  \"model\": \"{}\",\n  \"decode_steps\": {steps},\n  \"weight_bytes_f32\": {f32_bytes},\n  \"weight_bytes_int8\": {int8_bytes},\n  \"weight_reduction_x\": {weight_ratio:.4},\n  \"kv_bytes_per_token_f32\": {},\n  \"kv_bytes_per_token_u8\": {},\n  \"kv_tokens_capacity_f32\": {},\n  \"kv_tokens_capacity_u8\": {},\n  \"kv_capacity_x\": {kv_ratio:.4},\n  \"decode\": [\n{}\n  ]\n}}\n",
        cfg.name,
        kv_f32.bytes_per_token,
        kv_u8.bytes_per_token,
        kv_f32.tokens_capacity,
        kv_u8.tokens_capacity,
        decode_json.join(",\n"),
    );
    std::fs::write("BENCH_quant.json", &json).expect("write BENCH_quant.json");
    eprintln!("  wrote BENCH_quant.json");
}
