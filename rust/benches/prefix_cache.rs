//! Prefix-cache + swap bench: how much prefill work does automatic prefix
//! sharing save on a shared-system-prompt workload, and what does
//! swap-style preemption cost/recover under KV-pool pressure?
//!
//! Emits `BENCH_prefix_cache.json` (schema in EXPERIMENTS.md) plus the
//! usual JSON result lines on stdout. `SKIPLESS_BENCH_QUICK=1` shrinks the
//! workload for CI.

use skipless::config::ModelConfig;
use skipless::coordinator::{CpuEngine, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::CacheOpts;
use skipless::metrics::Metrics;
use skipless::model::ModelWeights;
use skipless::util::bench::fmt_dur;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct RunResult {
    tokens: Vec<Vec<u32>>,
    wall: Duration,
    prefilled: u64,
    saved: u64,
    hit_rate: f64,
    swap_outs: u64,
    swap_ins: u64,
    preemptions: u64,
}

fn run(
    w: &ModelWeights,
    prompts: &[Vec<u32>],
    max_new: usize,
    block_tokens: usize,
    budget: usize,
    opts: CacheOpts,
) -> RunResult {
    let metrics = Arc::new(Metrics::new());
    let mut s = Scheduler::new(
        CpuEngine::with_cache_opts(w.clone(), block_tokens, budget, opts),
        SchedulerCfg {
            max_running: 32,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    for (i, p) in prompts.iter().enumerate() {
        s.submit(Request::greedy(i as u64, p.clone(), max_new));
    }
    let t0 = Instant::now();
    let mut done = s.run_to_completion();
    let wall = t0.elapsed();
    done.sort_by_key(|r| r.id);
    RunResult {
        tokens: done.into_iter().map(|r| r.tokens).collect(),
        wall,
        prefilled: metrics.tokens_prefilled.load(Ordering::Relaxed),
        saved: metrics.kv_prefix_tokens_saved.load(Ordering::Relaxed),
        hit_rate: metrics.prefix_hit_rate(),
        swap_outs: metrics.kv_swap_outs.load(Ordering::Relaxed),
        swap_ins: metrics.kv_swap_ins.load(Ordering::Relaxed),
        preemptions: metrics.preemptions.load(Ordering::Relaxed),
    }
}

fn main() {
    println!("# prefix_cache — KV-block lifecycle: sharing + swap");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 2026);

    // Workload: N requests sharing a long system prompt + short unique
    // suffix — the RAG/chat shape prefix caching exists for.
    let n_requests = if quick { 8 } else { 24 };
    let system_len = 64usize;
    // keep max_new fixed so generation always crosses a block boundary in
    // the tight-pool section (that's what forces preemption)
    let max_new = 8;
    let vocab = cfg.vocab_size as u32;
    let system: Vec<u32> = (0..system_len as u32).map(|i| (i * 7 + 11) % vocab).collect();
    let prompts: Vec<Vec<u32>> = (0..n_requests as u32)
        .map(|i| {
            let mut p = system.clone();
            p.extend([(i * 13 + 1) % vocab, (i * 3 + 2) % vocab, (i + 5) % vocab]);
            p
        })
        .collect();
    let prompt_tokens: u64 = prompts.iter().map(|p| p.len() as u64).sum();

    // -- sharing off vs on, roomy pool ---------------------------------
    let off = run(
        &w,
        &prompts,
        max_new,
        16,
        64 << 20,
        CacheOpts { prefix_sharing: false, ..Default::default() },
    );
    let on = run(&w, &prompts, max_new, 16, 64 << 20, CacheOpts::default());
    assert_eq!(on.tokens, off.tokens, "sharing changed outputs");
    assert!(on.saved > 0, "no prefill tokens saved");
    assert!(on.hit_rate > 0.0, "prefix-hit rate must be > 0");
    assert_eq!(on.prefilled + on.saved, off.prefilled, "token accounting");

    let speedup = off.wall.as_secs_f64() / on.wall.as_secs_f64();
    eprintln!("  {} requests × {}+3-token prompts, {} prompt tokens total", n_requests, system_len, prompt_tokens);
    eprintln!(
        "  sharing off: prefilled {:>6} tokens   wall {}",
        off.prefilled,
        fmt_dur(off.wall)
    );
    eprintln!(
        "  sharing on : prefilled {:>6} tokens   wall {}   saved {} ({:.1}% hit rate)   {:.2}x",
        on.prefilled,
        fmt_dur(on.wall),
        on.saved,
        on.hit_rate * 100.0,
        speedup
    );
    println!(
        "{{\"suite\":\"prefix_cache\",\"case\":\"sharing\",\"requests\":{n_requests},\"prefill_tokens_baseline\":{},\"prefill_tokens_shared\":{},\"prefill_tokens_saved\":{},\"prefix_hit_rate\":{:.4},\"baseline_us\":{:.1},\"shared_us\":{:.1},\"speedup_x\":{speedup:.4}}}",
        off.prefilled,
        on.prefilled,
        on.saved,
        on.hit_rate,
        off.wall.as_secs_f64() * 1e6,
        on.wall.as_secs_f64() * 1e6,
    );

    // -- swap-style preemption under a tight pool ----------------------
    // pool ≈ 1/3 of what the workload wants at peak; preemption must kick
    // in and the streams must still match the roomy run byte for byte.
    let bytes_per_block = 2 * cfg.e() * cfg.n_layers * 4 * 8;
    let tight_blocks = n_requests + 4;
    let tight = run(
        &w,
        &prompts,
        max_new,
        8,
        tight_blocks * bytes_per_block,
        CacheOpts::default(),
    );
    assert_eq!(tight.tokens, on.tokens, "pressure changed outputs");
    assert!(
        tight.preemptions > 0,
        "tight pool never preempted — bench lost its bite"
    );
    eprintln!(
        "  tight pool ({} blocks): wall {}   swap_outs {}   swap_ins {}   preemptions {}",
        tight_blocks,
        fmt_dur(tight.wall),
        tight.swap_outs,
        tight.swap_ins,
        tight.preemptions
    );
    println!(
        "{{\"suite\":\"prefix_cache\",\"case\":\"swap_pressure\",\"pool_blocks\":{tight_blocks},\"swap_outs\":{},\"swap_ins\":{},\"preemptions\":{},\"wall_us\":{:.1}}}",
        tight.swap_outs,
        tight.swap_ins,
        tight.preemptions,
        tight.wall.as_secs_f64() * 1e6,
    );

    // -- machine-readable artifact -------------------------------------
    let json = format!(
        "{{\n  \"suite\": \"prefix_cache\",\n  \"model\": \"{}\",\n  \"requests\": {n_requests},\n  \"system_prompt_tokens\": {system_len},\n  \"prompt_tokens_total\": {prompt_tokens},\n  \"max_new_tokens\": {max_new},\n  \"prefill_tokens_baseline\": {},\n  \"prefill_tokens_shared\": {},\n  \"prefill_tokens_saved\": {},\n  \"prefix_hit_rate\": {:.4},\n  \"baseline_wall_us\": {:.1},\n  \"shared_wall_us\": {:.1},\n  \"speedup_x\": {speedup:.4},\n  \"swap\": {{\n    \"pool_blocks\": {tight_blocks},\n    \"swap_outs\": {},\n    \"swap_ins\": {},\n    \"preemptions\": {},\n    \"wall_us\": {:.1},\n    \"outputs_byte_identical\": true\n  }}\n}}\n",
        cfg.name,
        off.prefilled,
        on.prefilled,
        on.saved,
        on.hit_rate,
        off.wall.as_secs_f64() * 1e6,
        on.wall.as_secs_f64() * 1e6,
        tight.swap_outs,
        tight.swap_ins,
        tight.preemptions,
        tight.wall.as_secs_f64() * 1e6,
    );
    std::fs::write("BENCH_prefix_cache.json", &json).expect("write BENCH_prefix_cache.json");
    eprintln!("  wrote BENCH_prefix_cache.json");
}
