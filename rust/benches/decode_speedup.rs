//! THE headline bench: batch-1 (and small-batch) decode throughput,
//! vanilla vs Q/P-merged, on the real CPU engine — the measured
//! counterpart of the paper's "possible speedup: 1.17×/1.19×" row.
//!
//! The §3 model assumes decoding is weight-streaming-bound; on this CPU
//! testbed the ~100M model's weights (≫ L3 cache) must stream from DRAM
//! every step, so the *shape* of the paper's claim (merged faster by
//! roughly the removed-weight fraction at batch 1, advantage shrinking as
//! batch grows) is reproduced, while the absolute ratio depends on how
//! bandwidth-bound this machine is. Both measured and model-predicted
//! numbers are printed side by side.

use skipless::bandwidth::{predicted_speedup, Hardware, F32_BYTES};
use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{CpuEngine, DecodeInput, Engine};
use skipless::model::ModelWeights;
use skipless::surgery::{transform, Options};
use skipless::util::bench::{black_box, fmt_dur, Bencher};
use std::time::Instant;

/// Median decode-step time at a batch size.
fn step_time(eng: &mut CpuEngine, batch: usize, reps: usize) -> std::time::Duration {
    let prompt = [1u32, 2, 3, 4];
    let ids: Vec<_> = (0..batch).map(|_| eng.prefill(&prompt).unwrap().0).collect();
    let mut times = Vec::with_capacity(reps);
    let mut tok = 5u32;
    for _ in 0..2 {
        // warmup
        let inputs: Vec<_> = ids.iter().map(|&seq| DecodeInput { seq, token: tok }).collect();
        black_box(eng.decode_batch(&inputs).unwrap());
        tok += 1;
    }
    for _ in 0..reps {
        let inputs: Vec<_> = ids.iter().map(|&seq| DecodeInput { seq, token: tok }).collect();
        let t0 = Instant::now();
        black_box(eng.decode_batch(&inputs).unwrap());
        times.push(t0.elapsed());
        tok = (tok + 1) % 250;
    }
    for id in ids {
        eng.release(id);
    }
    times.sort_unstable();
    times[times.len() / 2]
}

fn main() {
    println!("# decode_speedup — paper §3 'possible speedup' measured");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = ModelConfig::e2e_100m();
    eprintln!(
        "model {}: GQA {}:{}, {} layers (≈100M params, weights ≫ LLC)",
        cfg.name, cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    );
    let vanilla_w = ModelWeights::init_vanilla(&cfg, 2024);
    let merged_w =
        transform(&vanilla_w, Variant::MergedQP, Options { skip_audit: true, ..Default::default() })
            .unwrap();
    let frac = 1.0
        - merged_w.stored_weights() as f64 / vanilla_w.stored_weights() as f64;
    eprintln!("Q/P removal: −{:.1}% of weights\n", frac * 100.0);

    let mut vanilla = CpuEngine::new(vanilla_w, 16, 512 << 20);
    let mut merged = CpuEngine::new(merged_w, 16, 512 << 20);
    let reps = if quick { 3 } else { 15 };

    eprintln!("  batch   vanilla/step   merged/step   measured   predicted(cpu-roofline)");
    let hw = Hardware::cpu_like();
    let batches: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let mut rows = Vec::new();
    for &bsz in batches {
        let tv = step_time(&mut vanilla, bsz, reps);
        let tm = step_time(&mut merged, bsz, reps);
        let measured = tv.as_secs_f64() / tm.as_secs_f64();
        let predicted = predicted_speedup(&cfg, Variant::MergedQP, &hw, bsz, 8, F32_BYTES);
        eprintln!(
            "  {:>5}   {:>12}   {:>11}   {:>8.3}x   {:>8.3}x",
            bsz,
            fmt_dur(tv),
            fmt_dur(tm),
            measured,
            predicted
        );
        rows.push((bsz, measured, predicted));
        println!(
            "{{\"suite\":\"decode_speedup\",\"batch\":{bsz},\"vanilla_us\":{:.1},\"merged_us\":{:.1},\"measured_x\":{measured:.4},\"predicted_x\":{predicted:.4}}}",
            tv.as_secs_f64() * 1e6,
            tm.as_secs_f64() * 1e6
        );
    }
    // shape assertions: merged must win at batch 1
    let (_, m1, _) = rows[0];
    assert!(
        m1 > 1.02,
        "merged should be measurably faster at batch 1, got {m1:.3}x"
    );
    eprintln!(
        "\n  paper (HBM accelerator, batch 1): 1.17x predicted for this weight fraction: {:.3}x",
        1.0 / (1.0 - frac)
    );

    // throughput view through the bench harness
    let mut b = Bencher::new("decode_speedup");
    let prompt = [1u32, 2, 3, 4];
    let (idv, _) = vanilla.prefill(&prompt).unwrap();
    let (idm, _) = merged.prefill(&prompt).unwrap();
    b.case_items("vanilla_decode_b1", Some(1.0), || {
        black_box(
            vanilla
                .decode_batch(&[DecodeInput { seq: idv, token: 9 }])
                .unwrap(),
        );
    });
    b.case_items("merged_decode_b1", Some(1.0), || {
        black_box(
            merged
                .decode_batch(&[DecodeInput { seq: idm, token: 9 }])
                .unwrap(),
        );
    });
    b.finish();
}
