//! Bench for Figs. 1(b,c,d) & 2: the serial weight merges.
//!
//! Measures (a) the *numerical equivalence error* of each transform — the
//! figure's claim is "mathematically identical", so the interesting series
//! is max relative logits error across variants/attention kinds/model
//! scales — and (b) the cost of surgery itself (LU solves dominate),
//! which a practitioner pays once per checkpoint.

use skipless::config::{ModelConfig, Variant};
use skipless::model::{prefill, ModelWeights};
use skipless::surgery::{transform, Options};
use skipless::util::bench::{black_box, Bencher};

fn equivalence_err(cfg: &ModelConfig, variant: Variant, seed: u64) -> f64 {
    let vanilla = ModelWeights::init_vanilla(cfg, seed);
    let merged = transform(&vanilla, variant, Options { skip_audit: true, ..Default::default() }).unwrap();
    let toks = [5u32, 17, 3, 42, 8, 1, 99, 100];
    let (l0, _) = prefill(&vanilla, &toks);
    let (l1, _) = prefill(&merged, &toks);
    l1.rel_fro_err(&l0)
}

fn main() {
    println!("# fig1_equivalence — serial merges (paper Figs. 1-2, Table 1)");

    eprintln!("\n{:<14} {:<11} {:>14}", "config", "variant", "rel logits err");
    let mut worst = 0.0f64;
    for (preset, variants) in [
        ("tiny-mha", vec![Variant::MergedQP, Variant::MergedKP, Variant::MergedVP]),
        ("tiny-gqa", vec![Variant::MergedQP]),
        ("tiny-mqa", vec![Variant::MergedQP]),
    ] {
        let cfg = ModelConfig::preset(preset).unwrap();
        for v in variants {
            let err = equivalence_err(&cfg, v, 7777);
            eprintln!("{:<14} {:<11} {:>14.3e}", preset, v.name(), err);
            worst = worst.max(err);
        }
    }
    // scale check: a deeper/wider model (100M) keeps roundoff-level error
    let big = ModelConfig::e2e_100m();
    let err_big = equivalence_err(&big, Variant::MergedQP, 31337);
    eprintln!("{:<14} {:<11} {:>14.3e}", "e2e-100m", "merged_qp", err_big);
    worst = worst.max(err_big);
    assert!(worst < 1e-3, "equivalence violated: {worst}");
    eprintln!("max rel err {worst:.3e} — within f32 roundoff ✓");

    // surgery cost (d=64 tiny vs d=640 100M-scale)
    let mut b = Bencher::new("fig1_equivalence");
    let tiny = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 1);
    b.case("surgery_qp(tiny-gqa d=64 L=2)", || {
        black_box(transform(&tiny, Variant::MergedQP, Options { skip_audit: true, ..Default::default() }).unwrap());
    });
    let mid = ModelWeights::init_vanilla(&ModelConfig::e2e_100m(), 2);
    b.case("surgery_qp(e2e-100m d=640 L=12)", || {
        black_box(transform(&mid, Variant::MergedQP, Options { skip_audit: true, ..Default::default() }).unwrap());
    });
    b.case("surgery_with_audit(e2e-100m)", || {
        black_box(transform(&mid, Variant::MergedQP, Options::default()).unwrap());
    });
    b.finish();
}
