//! Self-speculative decoding bench: INT8 draft → f32 verify, versus plain
//! batched decode.
//!
//! The quantity that matters on weight-streaming-bound hardware is **target
//! batched steps per generated token**: every plain step streams the full
//! f32 weights once to commit one token per sequence, while a verify step
//! streams them once to commit up to `k+1` tokens per sequence (the widened
//! step batches the draft positions through the same GEMMs). Greedy
//! acceptance keeps the output token-identical, so the comparison is pure
//! bookkeeping — both runs produce the same streams, asserted here. Emits
//! `BENCH_spec.json` (schema in EXPERIMENTS.md); `SKIPLESS_BENCH_QUICK=1`
//! shrinks the model and token counts for CI.

use skipless::config::{AttentionKind, BlockLayout, FfnKind, ModelConfig};
use skipless::coordinator::{CpuEngine, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::CacheOpts;
use skipless::metrics::Metrics;
use skipless::model::{quantize, ModelWeights};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Same mid-size GQA model as `quant_throughput`: big enough that decode is
/// genuinely weight-streaming-bound, small enough to init in seconds.
fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "spec-bench-85m".into(),
        dim: 384,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 2,
        hidden_dim: 1536,
        vocab_size: 1024,
        max_seq_len: 512,
        attention: AttentionKind::Gqa,
        layout: BlockLayout::Serial,
        ffn: FfnKind::Mlp,
        tied_embeddings: false,
    }
}

struct RunStats {
    tokens: Vec<Vec<u32>>,
    target_steps: u64,
    tokens_decoded: u64,
    drafted: u64,
    accepted: u64,
    draft_steps: u64,
    wall_s: f64,
}

fn run(
    w: &ModelWeights,
    spec_k: usize,
    prompts: &[Vec<u32>],
    max_new: usize,
    budget: usize,
) -> RunStats {
    let metrics = Arc::new(Metrics::new());
    let cfg = SchedulerCfg {
        spec_k,
        ..Default::default()
    };
    let engine = CpuEngine::new(w.clone(), 16, budget);
    let mut s = if spec_k > 0 {
        // the draft: the same weights at int8, with a u8 KV pool — draft
        // precision affects only the accept rate, never correctness
        let draft = CpuEngine::with_cache_opts(
            quantize(w),
            16,
            budget,
            CacheOpts {
                quantized: true,
                ..Default::default()
            },
        );
        Scheduler::with_draft(engine, Box::new(draft), cfg, Arc::clone(&metrics))
    } else {
        Scheduler::new(engine, cfg, Arc::clone(&metrics))
    };
    for (i, p) in prompts.iter().enumerate() {
        s.submit(Request::greedy(i as u64, p.clone(), max_new));
    }
    let t0 = Instant::now();
    let mut done = s.run_to_completion();
    let wall_s = t0.elapsed().as_secs_f64();
    done.sort_by_key(|r| r.id);
    RunStats {
        tokens: done.into_iter().map(|r| r.tokens).collect(),
        target_steps: metrics.batches_run.load(Ordering::Relaxed),
        tokens_decoded: metrics.tokens_decoded.load(Ordering::Relaxed),
        drafted: metrics.spec_tokens_drafted.load(Ordering::Relaxed),
        accepted: metrics.spec_tokens_accepted.load(Ordering::Relaxed),
        draft_steps: metrics.spec_draft_steps.load(Ordering::Relaxed),
        wall_s,
    }
}

fn main() {
    println!("# spec_decode — self-speculative decoding (int8 draft → f32 verify)");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = if quick { ModelConfig::tiny_gqa() } else { bench_config() };
    let (n_req, max_new) = if quick { (4, 12) } else { (8, 32) };
    let k = 4usize;
    let budget = 64 << 20;

    eprintln!("  initializing {} (this includes calibration)...", cfg.name);
    let w = ModelWeights::init_vanilla(&cfg, 2026);
    let vocab = cfg.vocab_size as u32;
    let prompts: Vec<Vec<u32>> = (0..n_req)
        .map(|i| (0..6).map(|j| ((i * 131 + j * 17 + 3) as u32) % vocab).collect())
        .collect();

    let plain = run(&w, 0, &prompts, max_new, budget);
    let spec = run(&w, k, &prompts, max_new, budget);

    // the headline guarantee: greedy speculative output is token-identical
    assert_eq!(
        plain.tokens, spec.tokens,
        "speculative decode changed the greedy output stream"
    );

    let spt_plain = plain.target_steps as f64 / plain.tokens_decoded.max(1) as f64;
    let spt_spec = spec.target_steps as f64 / spec.tokens_decoded.max(1) as f64;
    let reduction = spt_plain / spt_spec;
    let accept_rate = spec.accepted as f64 / spec.drafted.max(1) as f64;
    let wall_x = plain.wall_s / spec.wall_s.max(1e-12);
    eprintln!(
        "  plain: {} target steps / {} tokens ({:.4} steps/tok, {:.2}s)",
        plain.target_steps, plain.tokens_decoded, spt_plain, plain.wall_s
    );
    eprintln!(
        "  spec (k={k}): {} target steps / {} tokens ({:.4} steps/tok, {:.2}s), \
         accept {:.1}% ({}/{} drafts), {} draft steps",
        spec.target_steps,
        spec.tokens_decoded,
        spt_spec,
        spec.wall_s,
        100.0 * accept_rate,
        spec.accepted,
        spec.drafted,
        spec.draft_steps
    );
    eprintln!("  target-step reduction: {reduction:.2}x   wall-clock: {wall_x:.2}x");
    println!(
        "{{\"suite\":\"spec_decode\",\"case\":\"k{k}\",\"steps_per_token_plain\":{spt_plain:.4},\"steps_per_token_spec\":{spt_spec:.4},\"target_step_reduction_x\":{reduction:.4},\"accept_rate\":{accept_rate:.4}}}"
    );
    // acceptance bar (full mode): ≥ 1.5x fewer target-model batched steps
    // per generated token at k=4
    if !quick {
        assert!(
            reduction >= 1.5,
            "target-step reduction only {reduction:.2}x at k={k}"
        );
    }

    let json = format!(
        "{{\n  \"suite\": \"spec_decode\",\n  \"model\": \"{}\",\n  \"k\": {k},\n  \"requests\": {n_req},\n  \"max_new_tokens\": {max_new},\n  \"tokens_generated\": {},\n  \"identical_output\": true,\n  \"accept_rate\": {accept_rate:.4},\n  \"tokens_drafted\": {},\n  \"tokens_accepted\": {},\n  \"draft_steps\": {},\n  \"target_steps_plain\": {},\n  \"target_steps_spec\": {},\n  \"steps_per_token_plain\": {spt_plain:.4},\n  \"steps_per_token_spec\": {spt_spec:.4},\n  \"target_step_reduction_x\": {reduction:.4},\n  \"wall_plain_s\": {:.4},\n  \"wall_spec_s\": {:.4},\n  \"wall_speedup_x\": {wall_x:.4}\n}}\n",
        cfg.name,
        spec.tokens_decoded,
        spec.drafted,
        spec.accepted,
        spec.draft_steps,
        plain.target_steps,
        spec.target_steps,
        plain.wall_s,
        spec.wall_s,
    );
    std::fs::write("BENCH_spec.json", &json).expect("write BENCH_spec.json");
    eprintln!("  wrote BENCH_spec.json");
}
