//! Chunked-prefill bench: mixed interactive + long-prompt serving, chunked
//! (token-budgeted) versus full-prompt admission.
//!
//! The quantity that matters for a mixed workload is **interactive TTFT
//! under long-prompt interference**: with full-prompt admission, one long
//! prompt's prefill monopolizes an entire step, and every short request
//! that arrived behind it eats that wall time before its own (tiny)
//! prefill can run. With a token budget, the long prompt advances a chunk
//! per step while short requests admit, prefill, and decode alongside —
//! TTFT stays flat and decode throughput is preserved because chunk rows
//! share the fused step's weight traffic with the decode rows. Both modes
//! must produce byte-identical streams (chunked prefill is bit-identical
//! to monolithic; asserted here). Emits `BENCH_chunked_prefill.json`
//! (schema in EXPERIMENTS.md); `SKIPLESS_BENCH_QUICK=1` shrinks the model
//! and token counts for CI.

use skipless::config::{AttentionKind, BlockLayout, FfnKind, ModelConfig};
use skipless::coordinator::{CpuEngine, Request, Scheduler, SchedulerCfg};
use skipless::metrics::Metrics;
use skipless::model::ModelWeights;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Mid-size GQA model with room for a genuinely long prompt.
fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "chunked-bench-30m".into(),
        dim: 256,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 2,
        hidden_dim: 1024,
        vocab_size: 512,
        max_seq_len: 1536,
        attention: AttentionKind::Gqa,
        layout: BlockLayout::Serial,
        ffn: FfnKind::Mlp,
        tied_embeddings: false,
    }
}

struct Workload {
    shorts_a: Vec<Request>,
    long_req: Request,
    shorts_b: Vec<Request>,
}

fn workload(cfg: &ModelConfig, quick: bool) -> Workload {
    let vocab = cfg.vocab_size as u32;
    let (n_short, short_new, long_len, long_new) =
        if quick { (3usize, 8usize, 96usize, 4usize) } else { (3, 64, 768, 8) };
    let mk_short = |id: u64| {
        let prompt: Vec<u32> = (0..8).map(|j| (id as u32 * 37 + j * 11 + 1) % vocab).collect();
        Request::greedy(id, prompt, short_new)
    };
    Workload {
        shorts_a: (0..n_short as u64).map(mk_short).collect(),
        long_req: Request::greedy(
            100,
            (0..long_len).map(|j| (j as u32 * 13 + 7) % vocab).collect(),
            long_new,
        ),
        shorts_b: (n_short as u64..2 * n_short as u64).map(mk_short).collect(),
    }
}

struct RunStats {
    tokens: Vec<(u64, Vec<u32>)>,
    /// TTFT (from submission) of the interactive short requests, µs.
    short_ttft_us: Vec<u64>,
    decode_tok_per_s: f64,
    wall_s: f64,
    chunks: u64,
}

fn run(w: &ModelWeights, sched: SchedulerCfg, wl: &Workload, budget: usize) -> RunStats {
    let metrics = Arc::new(Metrics::new());
    let mut s = Scheduler::new(CpuEngine::new(w.clone(), 16, budget), sched, Arc::clone(&metrics));
    let t0 = Instant::now();
    // phase 1: interactive requests settle into steady decode
    for r in &wl.shorts_a {
        s.submit(r.clone());
    }
    s.step(); // admit + prefill
    s.step(); // first decode
    // phase 2: the long prompt lands with more interactive requests right
    // behind it — the head-of-line-blocking trap. Under full-prompt
    // admission the next step prefills all 768 long-prompt tokens before
    // any of these shorts can produce a token; under a token budget the
    // shorts admit and finish their tiny prefills alongside the first
    // chunk.
    s.submit(wl.long_req.clone());
    for r in &wl.shorts_b {
        s.submit(r.clone());
    }
    let mut done = s.run_to_completion();
    let wall_s = t0.elapsed().as_secs_f64();
    done.sort_by_key(|r| r.id);
    let short_ids: Vec<u64> = wl
        .shorts_a
        .iter()
        .chain(&wl.shorts_b)
        .map(|r| r.id)
        .collect();
    let short_ttft_us = done
        .iter()
        .filter(|r| short_ids.contains(&r.id))
        .map(|r| r.ttft.as_micros() as u64)
        .collect();
    let decoded = metrics.tokens_decoded.load(Ordering::Relaxed);
    RunStats {
        tokens: done.into_iter().map(|r| (r.id, r.tokens)).collect(),
        short_ttft_us,
        decode_tok_per_s: decoded as f64 / wall_s,
        wall_s,
        chunks: metrics.prefill_chunks.load(Ordering::Relaxed),
    }
}

fn p95(xs: &[u64]) -> u64 {
    let mut v = xs.to_vec();
    v.sort_unstable();
    v[((v.len() as f64 * 0.95).ceil() as usize - 1).min(v.len() - 1)]
}

fn main() {
    println!("# chunked_prefill — token-budgeted continuous batching vs full-prompt admission");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = if quick { ModelConfig::tiny_gqa() } else { bench_config() };
    eprintln!("  initializing {}...", cfg.name);
    let w = ModelWeights::init_vanilla(&cfg, 2027);
    let wl = workload(&cfg, quick);
    let pool = 256 << 20;

    let (tb, ct) = if quick { (24, 16) } else { (192, 128) };
    let chunked = run(
        &w,
        SchedulerCfg {
            token_budget_per_step: tb,
            chunk_tokens: ct,
            ..Default::default()
        },
        &wl,
        pool,
    );
    // "full" mode: budget and chunk far beyond any prompt — every
    // admission prefills its entire prompt inside one step
    let full = run(
        &w,
        SchedulerCfg {
            token_budget_per_step: usize::MAX / 2,
            chunk_tokens: usize::MAX / 2,
            ..Default::default()
        },
        &wl,
        pool,
    );

    // the correctness headline: budgeting changes WHEN work runs, never
    // what it computes
    assert_eq!(chunked.tokens, full.tokens, "chunking changed the generated streams");
    assert!(chunked.chunks > full.chunks, "budgeted run never actually chunked");

    let p95_chunked = p95(&chunked.short_ttft_us).max(1);
    let p95_full = p95(&full.short_ttft_us).max(1);
    let ttft_x = p95_full as f64 / p95_chunked as f64;
    let decode_ratio = chunked.decode_tok_per_s / full.decode_tok_per_s.max(1e-12);
    eprintln!(
        "  full    : short-TTFT p95 {:>9}µs   decode {:>8.1} tok/s   wall {:.2}s   {} chunks",
        p95_full, full.decode_tok_per_s, full.wall_s, full.chunks
    );
    eprintln!(
        "  chunked : short-TTFT p95 {:>9}µs   decode {:>8.1} tok/s   wall {:.2}s   {} chunks",
        p95_chunked, chunked.decode_tok_per_s, chunked.wall_s, chunked.chunks
    );
    eprintln!("  interactive p95 TTFT improvement: {ttft_x:.2}x   decode-throughput ratio: {decode_ratio:.2}");
    println!(
        "{{\"suite\":\"chunked_prefill\",\"case\":\"mixed\",\"ttft_p95_improvement_x\":{ttft_x:.4},\"decode_throughput_ratio\":{decode_ratio:.4}}}"
    );
    // acceptance bar (full mode): ≥2x interactive p95 TTFT improvement
    // with no decode-throughput regression
    if !quick {
        assert!(
            ttft_x >= 2.0,
            "p95 TTFT improved only {ttft_x:.2}x under the long-prompt mix"
        );
        assert!(
            decode_ratio >= 0.9,
            "chunking regressed decode throughput to {decode_ratio:.2}x"
        );
    }

    let json = format!(
        "{{\n  \"suite\": \"chunked_prefill\",\n  \"model\": \"{}\",\n  \"token_budget_per_step\": {tb},\n  \"chunk_tokens\": {ct},\n  \"long_prompt_tokens\": {},\n  \"interactive_requests\": {},\n  \"identical_output\": true,\n  \"prefill_chunks\": {},\n  \"ttft_p95_short_chunked_us\": {p95_chunked},\n  \"ttft_p95_short_full_us\": {p95_full},\n  \"ttft_p95_improvement_x\": {ttft_x:.4},\n  \"decode_tok_per_s_chunked\": {:.2},\n  \"decode_tok_per_s_full\": {:.2},\n  \"decode_throughput_ratio\": {decode_ratio:.4},\n  \"wall_chunked_s\": {:.4},\n  \"wall_full_s\": {:.4}\n}}\n",
        cfg.name,
        wl.long_req.prompt.len(),
        wl.shorts_a.len() + wl.shorts_b.len(),
        chunked.chunks,
        chunked.decode_tok_per_s,
        full.decode_tok_per_s,
        chunked.wall_s,
        full.wall_s,
    );
    std::fs::write("BENCH_chunked_prefill.json", &json).expect("write BENCH_chunked_prefill.json");
    eprintln!("  wrote BENCH_chunked_prefill.json");
}
