//! Bench for §4's invertibility experiment: "all square matrices of
//! Mistral-7B are invertible".
//!
//! Substitution (DESIGN.md): seeded Gaussian matrices at Mistral's exact
//! dimension d=4096, plus a sweep of smaller dims, plus adversarial
//! singular/near-singular cases to show the audit machinery actually
//! discriminates. Times LU factorization, inversion and κ₁ estimation —
//! the costs a checkpoint-surgery pipeline pays.

use skipless::config::ModelConfig;
use skipless::linalg::{cond_estimate, inverse, Lu, LuError};
use skipless::model::ModelWeights;
use skipless::surgery::{audit, audit_summary};
use skipless::tensor::Mat;
use skipless::util::bench::{black_box, Bencher};
use skipless::util::rng::Xoshiro256;

fn main() {
    println!("# invertibility — paper §4 audit");
    let mut rng = Xoshiro256::seed_from_u64(424242);

    // dim sweep: every matrix invertible, condition numbers moderate
    eprintln!("\n  dim     invertible   κ₁ estimate");
    for dim in [64usize, 256, 1024, 4096] {
        let m = Mat::randn(dim, dim, 1.0 / (dim as f32).sqrt(), &mut rng);
        match cond_estimate(&m) {
            Ok(k) => eprintln!("  {dim:<7} yes          {k:.3e}"),
            Err(e) => panic!("dim {dim} unexpectedly singular: {e}"),
        }
    }

    // Mistral-shaped audit: all Q and P matrices of a full 32-layer model
    // at reduced d (full d=4096 × 32 layers would take minutes; one full-d
    // sample above covers the paper's exact dimension).
    let mut cfg = ModelConfig::mistral_7b();
    cfg.dim = 512;
    cfg.hidden_dim = 1024;
    cfg.vocab_size = 1024;
    cfg.n_heads = 8;
    cfg.n_kv_heads = 2;
    cfg.name = "mistral-shaped-512".into();
    let w = ModelWeights::init_vanilla(&cfg, 31415);
    let rows = audit(&w);
    let (all_inv, worst) = audit_summary(&rows);
    eprintln!(
        "\n  mistral-shaped 32-layer audit: {} square matrices, all invertible = {all_inv}, worst κ₁ ≈ {worst:.3e}",
        rows.len()
    );
    assert!(all_inv);

    // adversarial: the audit must reject constructed singulars
    let mut sing = Mat::randn(128, 128, 0.1, &mut rng);
    let r0: Vec<f32> = sing.row(0).to_vec();
    sing.row_mut(127).copy_from_slice(&r0);
    assert!(matches!(Lu::factor(&sing), Err(LuError::Singular { .. })));
    eprintln!("  constructed rank-deficient 128×128: correctly rejected ✓");

    let mut b = Bencher::new("invertibility");
    let m256 = Mat::randn(256, 256, 1.0 / 16.0, &mut rng);
    let m1024 = Mat::randn(1024, 1024, 1.0 / 32.0, &mut rng);
    b.case("lu_factor(256)", || {
        black_box(Lu::factor(&m256).unwrap());
    });
    b.case("inverse(256)", || {
        black_box(inverse(&m256).unwrap());
    });
    b.case("cond_estimate(256)", || {
        black_box(cond_estimate(&m256).unwrap());
    });
    b.case("lu_factor(1024)", || {
        black_box(Lu::factor(&m1024).unwrap());
    });
    b.case("cond_estimate(1024)", || {
        black_box(cond_estimate(&m1024).unwrap());
    });
    b.finish();
}
