//! Zero-copy paged attention bench: decode-step attention over block views
//! (threaded (sequence × head) grid, no copies) versus the old
//! gather-then-attend path (per-sequence memcpy of the full rotated-K/V
//! history into scratch, serial scalar kernel) — at t ∈ {256, 2048}.
//!
//! Both paths produce BIT-identical outputs (asserted here; the property
//! suite in `tests/paged_attn_equiv.rs` covers the full grid), so the
//! comparison is pure data movement + parallelism. Also runs a steady-state
//! engine decode and asserts, via the cache stats behind the new `attn.*`
//! metrics, that the hot path performs ZERO gather copies. Emits
//! `BENCH_paged_attn.json` (schema in EXPERIMENTS.md);
//! `SKIPLESS_BENCH_QUICK=1` shrinks history lengths for CI.

use skipless::config::{AttentionKind, BlockLayout, FfnKind, ModelConfig};
use skipless::coordinator::{CpuEngine, DecodeInput, Engine};
use skipless::kvcache::{BlockView, KvCache, SeqId};
use skipless::model::attention::HeadLayout;
use skipless::model::paged_attn::{attend_batch, attend_gathered, AttnItem, KvSegment};
use skipless::model::ModelWeights;
use skipless::tensor::Mat;
use skipless::util::bench::{black_box, Bencher};
use skipless::util::rng::Xoshiro256;

/// Mistral-like head geometry scaled down: GQA 8q/2kv, hd=48 → e = 96.
fn bench_config() -> ModelConfig {
    ModelConfig {
        name: "paged-attn-bench".into(),
        dim: 384,
        n_layers: 2,
        n_heads: 8,
        n_kv_heads: 2,
        hidden_dim: 768,
        vocab_size: 256,
        max_seq_len: 4096,
        attention: AttentionKind::Gqa,
        layout: BlockLayout::Serial,
        ffn: FfnKind::Mlp,
        tied_embeddings: false,
    }
}

fn fill(c: &mut KvCache, cfg: &ModelConfig, id: SeqId, n: usize, rng: &mut Xoshiro256) {
    let e = cfg.e();
    for _ in 0..n {
        for layer in 0..cfg.n_layers {
            let k = Mat::randn(1, e, 0.7, rng);
            let v = Mat::randn(1, e, 0.7, rng);
            c.append(id, layer, k.row(0), v.row(0)).unwrap();
        }
        c.advance(id).unwrap();
    }
}

struct Case {
    t: usize,
    rows_per_s_gather: f64,
    rows_per_s_paged: f64,
    speedup: f64,
    gather_copy_bytes_per_step: u64,
    paged_read_bytes_per_step: u64,
}

fn run_case(cfg: &ModelConfig, t: usize, batch: usize, b: &mut Bencher) -> Case {
    let layout = HeadLayout {
        n_heads: cfg.n_heads,
        n_kv_heads: cfg.n_kv_heads,
        head_dim: cfg.head_dim(),
    };
    let e = cfg.e();
    let budget = (batch + 1) * t * cfg.n_layers * 2 * e * 4 * 2;
    let mut cache = KvCache::new(cfg, 16, budget);
    let mut rng = Xoshiro256::seed_from_u64(2027);
    let ids: Vec<SeqId> = (0..batch)
        .map(|_| {
            let id = cache.alloc_seq(t).unwrap();
            fill(&mut cache, cfg, id, t, &mut rng);
            id
        })
        .collect();
    let q = Mat::randn(batch, layout.d(), 0.5, &mut rng);
    let cur = Mat::randn(batch, 2 * e, 0.5, &mut rng);

    // --- old path: gather each sequence's history into scratch, attend
    // serially (exactly the pre-change decode-step attention)
    let mut out_g = Mat::zeros(batch, layout.d());
    let (mut sk, mut sv) = (Vec::new(), Vec::new());
    let g0 = cache.stats();
    let sg = b.case_items(&format!("gather_attend_t{t}_b{batch}"), Some(batch as f64), || {
        for (r, &id) in ids.iter().enumerate() {
            cache.gather(id, 0, &mut sk, &mut sv).unwrap();
            sk.extend_from_slice(&cur.row(r)[..e]);
            sv.extend_from_slice(&cur.row(r)[e..]);
            attend_gathered(layout, q.row(r), &sk, &sv, t + 1, out_g.row_mut(r));
        }
        black_box(out_g.at(0, 0));
    });
    let rows_per_s_gather = sg.items_per_sec().unwrap();
    let gathers_run = (cache.stats().gathers - g0.gathers).max(1);
    let gather_copy_bytes_per_step =
        (cache.stats().gather_bytes - g0.gather_bytes) / gathers_run * batch as u64;

    // --- paged path: zero-copy views, threaded (sequence × head) grid
    let mut out_p = Mat::zeros(batch, layout.d());
    let views: Vec<BlockView> = ids
        .iter()
        .flat_map(|&id| cache.seq_block_views(id, 0).unwrap().collect::<Vec<_>>())
        .collect();
    let blocks_per_seq = views.len() / batch;
    let sp = b.case_items(&format!("paged_attend_t{t}_b{batch}"), Some(batch as f64), || {
        let items: Vec<AttnItem> = (0..batch)
            .map(|r| AttnItem {
                q_rot: q.row(r),
                views: &views[r * blocks_per_seq..(r + 1) * blocks_per_seq],
                cache_len: t,
                tails: [
                    KvSegment::rows(&cur.row(r)[..e], &cur.row(r)[e..], e),
                    KvSegment::empty(),
                ],
                t: t + 1,
                out_row: r,
            })
            .collect();
        attend_batch(layout, &items, &mut out_p);
        black_box(out_p.at(0, 0));
    });
    let rows_per_s_paged = sp.items_per_sec().unwrap();

    assert_eq!(
        out_g.as_slice(),
        out_p.as_slice(),
        "t={t}: paged output diverged from the gather reference"
    );
    let paged_read_bytes_per_step = (batch * t * 2 * e * 4) as u64;
    Case {
        t,
        rows_per_s_gather,
        rows_per_s_paged,
        speedup: rows_per_s_paged / rows_per_s_gather,
        gather_copy_bytes_per_step,
        paged_read_bytes_per_step,
    }
}

/// Steady-state serving check: a real engine decoding a batch must read the
/// cache exclusively through views — zero gather copies, counted by the
/// same stats the `attn.*` serving metrics expose.
fn assert_zero_gather_decode(cfg: &ModelConfig) -> u64 {
    let w = ModelWeights::init_vanilla(cfg, 7);
    let mut eng = CpuEngine::new(w, 16, 64 << 20);
    let ids: Vec<SeqId> = (0..4)
        .map(|i| eng.prefill(&[1 + i, 2, 3, 4, 5, 6]).unwrap().0)
        .collect();
    let before = eng.cache().stats();
    for step in 0..8u32 {
        let batch: Vec<DecodeInput> = ids
            .iter()
            .map(|&seq| DecodeInput { seq, token: 1 + step % 7 })
            .collect();
        eng.decode_batch(&batch).unwrap();
    }
    let after = eng.cache().stats();
    assert_eq!(
        after.gathers, before.gathers,
        "steady-state decode must perform zero gather copies"
    );
    let paged = after.paged_reads_bytes - before.paged_reads_bytes;
    assert!(paged > 0, "paged reads must be accounted");
    paged
}

fn main() {
    println!("# paged_attn — zero-copy paged attention vs gather+attend");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let cfg = bench_config();
    let batch = 4usize;
    let ts: &[usize] = if quick { &[64, 128] } else { &[256, 2048] };

    let mut b = Bencher::new("paged_attn");
    let cases: Vec<Case> = ts.iter().map(|&t| run_case(&cfg, t, batch, &mut b)).collect();
    let steady_paged_bytes = assert_zero_gather_decode(&cfg);
    b.finish();

    for c in &cases {
        eprintln!(
            "  t={:>5}: gather {:>10.1} rows/s  paged {:>10.1} rows/s  ({:.2}x), \
             {:.1} KiB copy avoided per step",
            c.t,
            c.rows_per_s_gather,
            c.rows_per_s_paged,
            c.speedup,
            c.gather_copy_bytes_per_step as f64 / 1024.0
        );
    }
    // acceptance bar (full mode): ≥ 1.5x decode attention throughput at
    // t=2048, batch ≥ 4, on top of the zero-gather guarantee above
    if !quick {
        let long = cases.iter().find(|c| c.t == 2048).unwrap();
        assert!(
            long.speedup >= 1.5,
            "paged attention only {:.2}x over gather at t=2048",
            long.speedup
        );
    }

    let case_json: Vec<String> = cases
        .iter()
        .map(|c| {
            format!(
                "    {{\"t\": {}, \"batch\": {batch}, \"rows_per_s_gather\": {:.1}, \
                 \"rows_per_s_paged\": {:.1}, \"speedup_x\": {:.4}, \
                 \"gather_copy_bytes_per_step\": {}, \"paged_read_bytes_per_step\": {}}}",
                c.t,
                c.rows_per_s_gather,
                c.rows_per_s_paged,
                c.speedup,
                c.gather_copy_bytes_per_step,
                c.paged_read_bytes_per_step
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"suite\": \"paged_attn\",\n  \"model\": \"{}\",\n  \"layout\": \"gqa 8q/2kv hd48\",\n  \
         \"steady_state_gather_calls\": 0,\n  \"steady_state_paged_reads_bytes\": {steady_paged_bytes},\n  \
         \"cases\": [\n{}\n  ]\n}}\n",
        cfg.name,
        case_json.join(",\n")
    );
    std::fs::write("BENCH_paged_attn.json", &json).expect("write BENCH_paged_attn.json");
    eprintln!("  wrote BENCH_paged_attn.json");
}
