//! Tensor-parallel serving bench: decode throughput at 1/2/4 shard
//! workers, with BIT-identity against the single engine asserted in every
//! mode before any timing is trusted.
//!
//! Why TP helps on one machine at all: batched decode is GEMV-shaped (a
//! handful of rows), so the blocked GEMM kernels cannot spread one matrix
//! over many cores by rows — sharding splits the *columns* (head groups)
//! across workers with their own thread pools, and the per-shard
//! attention walks only its own KV slice. The joins are memcpy
//! concatenations plus a full-width host FFN (see DESIGN.md §Sharding),
//! so correctness is exact, not approximate — the identity check here is
//! `assert_eq!` on f32 logits, no tolerance.
//!
//! Emits `BENCH_sharding.json` (schema in EXPERIMENTS.md). Full mode
//! asserts the scaling SLO: ≥1.5x decode throughput at 4 workers versus
//! 1. `SKIPLESS_BENCH_QUICK=1` shrinks the model and skips the SLO (a
//! loaded CI box can't promise scaling), keeping the identity checks.

use skipless::config::ModelConfig;
use skipless::coordinator::{CpuEngine, DecodeInput, Engine, ShardedEngine};
use skipless::model::ModelWeights;
use std::time::Instant;

const BLOCK_TOKENS: usize = 16;
const BUDGET: usize = 256 << 20;

fn argmax(row: &[f32]) -> u32 {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u32
}

/// The bench model: MHA so every worker count in {1, 2, 4} divides the KV
/// heads. Full mode is sized so a decode step is dominated by the
/// projections and attention the shards split.
fn bench_cfg(quick: bool) -> ModelConfig {
    let mut cfg = ModelConfig::tiny_mha();
    cfg.name = if quick { "bench-tp-quick".into() } else { "bench-tp".into() };
    if !quick {
        cfg.dim = 512;
        cfg.n_heads = 8;
        cfg.n_kv_heads = 8;
        cfg.n_layers = 6;
        cfg.hidden_dim = 1408;
        cfg.vocab_size = 1024;
        cfg.max_seq_len = 512;
    }
    cfg
}

struct RunResult {
    tok_s: f64,
    wall_s: f64,
    logits_trace: Vec<Vec<f32>>,
    allreduce_calls: u64,
    allreduce_bytes: u64,
}

/// Prefill `batch` prompts and greedy-decode `steps` tokens for each,
/// batched, timing only the decode loop. The first sequence's logits rows
/// come back as the bit-identity witness.
fn run(engine: &mut Box<dyn Engine>, batch: usize, prompt_len: usize, steps: usize) -> RunResult {
    let vocab = engine.cfg().vocab_size as u32;
    let mut seqs = Vec::with_capacity(batch);
    let mut toks = Vec::with_capacity(batch);
    for b in 0..batch {
        let prompt: Vec<u32> =
            (0..prompt_len).map(|i| ((i * 13 + b * 29 + 7) as u32) % vocab).collect();
        let (seq, logits) = engine.prefill(&prompt).expect("prefill");
        seqs.push(seq);
        toks.push(argmax(&logits));
    }
    let mut logits_trace = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for _ in 0..steps {
        let inputs: Vec<DecodeInput> = seqs
            .iter()
            .zip(&toks)
            .map(|(&seq, &token)| DecodeInput { seq, token })
            .collect();
        let rows = engine.decode_batch(&inputs).expect("decode");
        logits_trace.push(rows[0].clone());
        for (t, row) in toks.iter_mut().zip(&rows) {
            *t = argmax(row);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    for seq in seqs {
        engine.release(seq);
    }
    let (allreduce_calls, allreduce_bytes) = engine
        .shard_stats()
        .map(|s| (s.allreduce_calls, s.allreduce_bytes))
        .unwrap_or((0, 0));
    RunResult {
        tok_s: (batch * steps) as f64 / wall_s,
        wall_s,
        logits_trace,
        allreduce_calls,
        allreduce_bytes,
    }
}

fn main() {
    println!("# sharded_serving — tensor-parallel decode throughput + bit-identity");
    let quick = std::env::var("SKIPLESS_BENCH_QUICK").is_ok();
    let (batch, prompt_len, steps) = if quick { (4usize, 12usize, 8usize) } else { (8, 64, 48) };
    let cfg = bench_cfg(quick);
    let w = ModelWeights::init_vanilla(&cfg, 4041);
    eprintln!(
        "  model {} (d={}, {} layers, {}/{} heads), batch {batch}, {steps} decode steps",
        cfg.name, cfg.dim, cfg.n_layers, cfg.n_heads, cfg.n_kv_heads
    );

    let mut rows = Vec::new();
    let mut baseline: Option<RunResult> = None;
    let mut speedup4 = 0.0f64;
    for workers in [1usize, 2, 4] {
        let mut engine: Box<dyn Engine> = if workers == 1 {
            Box::new(CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET))
        } else {
            Box::new(
                ShardedEngine::new(w.clone(), workers, BLOCK_TOKENS, BUDGET).expect("shardable"),
            )
        };
        let r = run(&mut engine, batch, prompt_len, steps);
        let scaling = baseline.as_ref().map(|b| r.tok_s / b.tok_s).unwrap_or(1.0);
        if workers == 4 {
            speedup4 = scaling;
        }
        // the whole point: every sharded logits row equals the single
        // engine's, byte for byte, before any throughput number counts
        if let Some(b) = &baseline {
            assert_eq!(
                r.logits_trace, b.logits_trace,
                "{workers}-way sharded decode diverged from the single engine"
            );
        }
        eprintln!(
            "  workers {workers}: {:.1} tok/s ({:.3}s wall, {:.2}x vs 1, allreduce {} calls / {} B)",
            r.tok_s, r.wall_s, scaling, r.allreduce_calls, r.allreduce_bytes
        );
        println!(
            "{{\"suite\":\"sharding\",\"case\":\"decode\",\"workers\":{workers},\"tok_s\":{:.1},\"scaling_x\":{scaling:.3},\"bit_identical\":true}}",
            r.tok_s
        );
        rows.push(format!(
            "    {{ \"workers\": {workers}, \"tok_s\": {:.2}, \"scaling_x\": {scaling:.4}, \
             \"allreduce_calls\": {}, \"allreduce_bytes\": {}, \"bit_identical\": true }}",
            r.tok_s, r.allreduce_calls, r.allreduce_bytes
        ));
        if workers == 1 {
            baseline = Some(r);
        }
    }

    let json = format!(
        "{{\n  \"suite\": \"sharding\",\n  \"model\": \"{}\",\n  \"dim\": {},\n  \"n_layers\": {},\n  \"batch\": {batch},\n  \"prompt_len\": {prompt_len},\n  \"decode_steps\": {steps},\n  \"quick\": {quick},\n  \"speedup_at_4\": {speedup4:.4},\n  \"runs\": [\n{}\n  ]\n}}\n",
        cfg.name,
        cfg.dim,
        cfg.n_layers,
        rows.join(",\n")
    );
    std::fs::write("BENCH_sharding.json", &json).expect("write BENCH_sharding.json");
    eprintln!("  wrote BENCH_sharding.json");

    if !quick {
        // scaling SLO: 4 shard workers must buy at least 1.5x decode
        // throughput on the full-size model
        assert!(
            speedup4 >= 1.5,
            "4-worker decode speedup {speedup4:.2}x missed the 1.5x SLO"
        );
    }
}
