//! Offline **stub** of the `xla` PJRT bindings.
//!
//! The serving stack's PJRT engine (`skipless::runtime`) is written against
//! the real `xla` crate's API. This container image has no PJRT plugin and
//! no crates.io access, so this path dependency provides the same surface
//! with a single behavior: [`PjRtClient::cpu`] returns an error, which
//! `PjrtEngine::boot` reports cleanly ("PJRT backend not available"). The
//! CPU engine path — everything the tier-1 tests exercise — is unaffected.
//!
//! On a machine with the real bindings, point the `xla` dependency in
//! `rust/Cargo.toml` at them; no source changes are needed.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT backend not available in this offline build (xla stub)".into(),
    ))
}

pub struct PjRtClient;
pub struct PjRtBuffer;
pub struct PjRtLoadedExecutable;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;
/// One element of `execute_b`'s per-device output list.
pub struct ExecOutput;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<ExecOutput>>, Error> {
        unavailable()
    }
}

impl ExecOutput {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}
