//! Full-stack serving integration (no artifacts required): tokenizer →
//! TCP server → coordinator → CPU engine → sampler, plus the weight-file
//! and surgery round-trips a deployment would perform.

use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{Coordinator, CpuEngine, Request, SchedulerCfg};
use skipless::kvcache::CacheOpts;
use skipless::metrics::Metrics;
use skipless::model::{greedy_generate, quantize, weights_io, ModelWeights};
use skipless::server::{generate_req, Client, Server, ServerCfg};
use skipless::surgery::{transform, Options};
use skipless::tokenizer::Bpe;
use skipless::util::json::Json;
use std::sync::Arc;

fn boot_engine(eng: CpuEngine) -> std::net::SocketAddr {
    let coord = Coordinator::spawn(eng, SchedulerCfg::default());
    let server = Server::bind("127.0.0.1:0", coord).unwrap();
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.serve();
    });
    addr
}

/// Boot with explicit server limits; also hands back the metrics registry
/// so tests can assert server-side gauges without a wire round-trip.
fn boot_cfg(w: ModelWeights, cfg: ServerCfg) -> (std::net::SocketAddr, Arc<Metrics>) {
    let coord = Coordinator::spawn(CpuEngine::new(w, 8, 32 << 20), SchedulerCfg::default());
    let metrics = Arc::clone(coord.metrics());
    let server = Server::bind_with("127.0.0.1:0", coord, cfg).unwrap();
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.serve();
    });
    (addr, metrics)
}

fn boot_server(w: ModelWeights) -> std::net::SocketAddr {
    boot_engine(CpuEngine::new(w, 8, 32 << 20))
}

#[test]
fn text_in_text_out_through_the_whole_stack() {
    let corpus = "the cat sat on the mat. the dog sat on the log. the cat and the dog sat.";
    let bpe = Bpe::train(corpus, 256 + 40);
    let mut cfg = ModelConfig::tiny_gqa();
    cfg.vocab_size = bpe.vocab_size().max(cfg.vocab_size);
    let w = ModelWeights::init_vanilla(&cfg, 7);
    let want = greedy_generate(&w, &bpe.encode("the cat"), 6);

    let addr = boot_server(w);
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let got = client.generate(&bpe.encode("the cat"), 6).unwrap();
    assert_eq!(got, want);
    // decodes back to *some* bytes losslessly
    let text = bpe.decode_lossy(&got);
    assert!(!text.is_empty());
}

#[test]
fn merged_server_serves_identical_text() {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 8);
    let m = transform(&w, Variant::MergedQP, Options::default()).unwrap();
    let addr_v = boot_server(w);
    let addr_m = boot_server(m);
    let mut cv = Client::connect(&addr_v.to_string()).unwrap();
    let mut cm = Client::connect(&addr_m.to_string()).unwrap();
    for prompt in [vec![1u32, 2, 3], vec![200, 100], vec![42; 5]] {
        let a = cv.generate(&prompt, 7).unwrap();
        let b = cm.generate(&prompt, 7).unwrap();
        assert_eq!(a, b, "prompt {prompt:?}");
    }
}

/// `{"op":"cancel"}` is wired through to the scheduler: unknown ids report
/// a clean false, a client-chosen id echoes back on generate, and a
/// finished request can no longer be cancelled.
#[test]
fn cancel_op_over_the_wire() {
    let cfg = ModelConfig::tiny_gqa();
    let addr = boot_server(ModelWeights::init_vanilla(&cfg, 14));
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let r = c
        .call(&Json::parse(r#"{"op":"cancel","id":777}"#).unwrap())
        .unwrap();
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(r.get("cancelled").unwrap().as_bool(), Some(false));
    // client-chosen id round-trips through generate...
    let g = c
        .call(
            &Json::parse(r#"{"op":"generate","prompt":[1,2,3],"max_new_tokens":3,"id":777}"#)
                .unwrap(),
        )
        .unwrap();
    assert_eq!(g.get("id").unwrap().as_u64(), Some(777));
    assert_eq!(g.get("finish").unwrap().as_str(), Some("length"));
    // ...and once finished it cannot be cancelled anymore
    let r = c
        .call(&Json::parse(r#"{"op":"cancel","id":777}"#).unwrap())
        .unwrap();
    assert_eq!(r.get("cancelled").unwrap().as_bool(), Some(false));
}

#[test]
fn sampling_requests_over_the_wire_are_seed_deterministic() {
    let cfg = ModelConfig::tiny_mha();
    let addr = boot_server(ModelWeights::init_vanilla(&cfg, 9));
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let req = Json::parse(
        r#"{"op":"generate","prompt":[4,5,6],"max_new_tokens":10,
            "temperature":0.9,"top_k":20,"top_p":0.9,"seed":1234}"#,
    )
    .unwrap();
    let r1 = c.call(&req).unwrap();
    let r2 = c.call(&req).unwrap();
    assert_eq!(r1.get("tokens"), r2.get("tokens"), "same seed, same stream");
    // different seed → (almost surely) different stream
    let req2 = Json::parse(
        r#"{"op":"generate","prompt":[4,5,6],"max_new_tokens":10,
            "temperature":0.9,"top_k":20,"top_p":0.9,"seed":99}"#,
    )
    .unwrap();
    let r3 = c.call(&req2).unwrap();
    assert_ne!(r1.get("tokens"), r3.get("tokens"));
}

#[test]
fn surgery_file_roundtrip_serves_equivalently() {
    // init → save → surgery → save → load → serve: the deployment path.
    let dir = std::env::temp_dir().join("skipless_serving_it");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::tiny_mqa();
    let w = ModelWeights::init_vanilla(&cfg, 10);
    let vanilla_path = dir.join("m.swt");
    weights_io::save(&w, &vanilla_path).unwrap();

    let loaded = weights_io::load(&vanilla_path).unwrap();
    let merged = transform(&loaded, Variant::MergedQP, Options::default()).unwrap();
    let merged_path = dir.join("m.merged.swt");
    weights_io::save(&merged, &merged_path).unwrap();

    let served = weights_io::load(&merged_path).unwrap();
    assert_eq!(served.variant, Variant::MergedQP);
    let want = greedy_generate(&w, &[3, 1, 4], 6);
    let got = greedy_generate(&served, &[3, 1, 4], 6);
    assert_eq!(got, want, "deployment roundtrip changed the function");
}

/// Regression: `{"op":"metrics"}` must expose the `kv_cache` lifecycle
/// object AND the quantization counters, with values that reflect an INT8 +
/// u8-KV engine actually doing work.
#[test]
fn metrics_expose_kv_and_quant_counters_over_the_wire() {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 12);
    let q = quantize(&w);
    let f32_bytes = q.stored_bytes();
    let resident = q.resident_bytes();
    let addr = boot_engine(CpuEngine::with_cache_opts(
        q,
        8,
        32 << 20,
        CacheOpts {
            quantized: true,
            ..Default::default()
        },
    ));
    let mut c = Client::connect(&addr.to_string()).unwrap();
    // three identical long prompts: the 2nd and 3rd hit the prefix cache.
    // The cold run attends over in-register f32 K/V while warm runs re-read
    // u8 codes, so cold-vs-warm may differ by a quantization step — but the
    // two warm runs read the very same codes and must agree byte for byte.
    let prompt: Vec<u32> = (0..20).map(|i| (i * 7 + 3) % 250).collect();
    let _cold = c.generate(&prompt, 4).unwrap();
    let warm1 = c.generate(&prompt, 4).unwrap();
    let warm2 = c.generate(&prompt, 4).unwrap();
    assert_eq!(warm1, warm2, "warm int8 serving must stay deterministic");

    let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    let metrics = m.get("metrics").unwrap();
    // kernel dispatch gauge: present and one of the known backends
    let dispatch = metrics.get("simd_dispatch").unwrap().as_str().unwrap();
    assert!(
        ["scalar", "avx2", "neon"].contains(&dispatch),
        "unexpected simd_dispatch {dispatch:?}"
    );
    let kv = metrics.get("kv_cache").unwrap();
    // lifecycle counters present and live
    assert!(kv.get("prefix_tokens_saved").unwrap().as_u64().unwrap() > 0);
    assert!(kv.get("prefix_hit_rate").unwrap().as_f64().unwrap() > 0.0);
    for key in [
        "cow_copies",
        "evictions",
        "swap_outs",
        "swap_ins",
        "blocks_used",
        "blocks_free",
        "blocks_cached",
    ] {
        assert!(kv.get(key).is_some(), "kv_cache.{key} missing");
    }
    // u8-KV pool: bytes/token shrinks and finished prompts stay cached
    let bpt = kv.get("bytes_per_token").unwrap().as_u64().unwrap();
    assert_eq!(bpt, ((2 * cfg.e() + 16) * cfg.n_layers) as u64);
    // tiny-gqa has e = 16, where the per-row meta is a big fraction (2.7x);
    // at realistic e the ratio approaches 4x (see kvcache unit tests)
    assert!(bpt * 2 < (2 * cfg.e() * 4 * cfg.n_layers) as u64);
    assert!(
        kv.get("blocks_cached").unwrap().as_u64().unwrap() > 0,
        "finished prompt blocks should sit in the reclaimable prefix cache"
    );
    // paged attention: decode/warm-prefill attention read the u8 pool in
    // place — live byte counters, and NOT ONE gather copy on the hot path
    let attn = metrics.get("attn").unwrap();
    assert!(
        attn.get("paged_reads_bytes").unwrap().as_u64().unwrap() > 0,
        "decode must read the paged pool in place"
    );
    assert!(
        attn.get("gather_bytes_avoided").unwrap().as_u64().unwrap()
            > attn.get("paged_reads_bytes").unwrap().as_u64().unwrap(),
        "u8 pool: in-place bytes must undercut the avoided f32 copy"
    );
    assert_eq!(
        attn.get("gather_calls").unwrap().as_u64(),
        Some(0),
        "the serving path must never gather-copy KV"
    );
    // continuous batching: admissions ran as budgeted prefill chunks and
    // the planner gauges are live
    let prefill = metrics.get("prefill").unwrap();
    assert!(
        prefill.get("chunks").unwrap().as_u64().unwrap() >= 3,
        "every admission should have run as at least one prefill chunk"
    );
    assert!(prefill.get("chunk_tokens").unwrap().as_u64().unwrap() > 0);
    let budget = metrics.get("budget").unwrap();
    assert_eq!(budget.get("token_limit").unwrap().as_u64(), Some(2048));
    assert!(budget.get("utilization").unwrap().as_f64().is_some());
    // weight-side quant counters match the engine's model exactly
    let quant = metrics.get("quant").unwrap();
    assert_eq!(quant.get("weight_bytes_f32").unwrap().as_u64(), Some(f32_bytes));
    assert_eq!(
        quant.get("weight_bytes_resident").unwrap().as_u64(),
        Some(resident)
    );
    assert_eq!(
        quant.get("weight_bytes_saved").unwrap().as_u64(),
        Some(f32_bytes - resident)
    );
}

/// An f32 server must report zero quantization savings (the counters exist
/// but read "nothing quantized here").
#[test]
fn f32_server_reports_no_quant_savings() {
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 13);
    let addr = boot_server(w);
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let _ = c.generate(&[1, 2, 3], 2).unwrap();
    let m = c.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    let metrics = m.get("metrics").unwrap();
    assert_eq!(
        metrics.get("quant").unwrap().get("weight_bytes_saved").unwrap().as_u64(),
        Some(0)
    );
    assert_eq!(
        metrics.get("kv_cache").unwrap().get("quantized_blocks").unwrap().as_u64(),
        Some(0)
    );
}

#[test]
fn concurrent_load_with_metrics() {
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 11);
    let coord = Coordinator::spawn(CpuEngine::new(w, 8, 32 << 20), SchedulerCfg::default());
    let coord = Arc::new(coord);
    let handles: Vec<_> = (0..12u64)
        .map(|i| {
            let c = Arc::clone(&coord);
            std::thread::spawn(move || {
                let r = c.generate(Request::greedy(i, vec![(i % 7 + 1) as u32, 2], 5));
                assert_eq!(r.tokens.len(), 5);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    use std::sync::atomic::Ordering;
    let m = coord.metrics();
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 12);
    assert_eq!(m.tokens_decoded.load(Ordering::Relaxed), 60);
    assert!(m.e2e.count() == 12);
}

// ---- reactor concurrency suite -----------------------------------------

fn add_fields(req: &mut Json, fields: Vec<(&str, Json)>) {
    if let Json::Obj(o) = req {
        for (k, v) in fields {
            o.insert(k.to_string(), v);
        }
    }
}

fn wait_until(mut cond: impl FnMut() -> bool, what: &str) {
    for _ in 0..2000 {
        if cond() {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    panic!("timed out waiting for {what}");
}

/// A reader that never drains its stream must not grow server memory: the
/// per-connection write queue stays bounded by its cap (+ at most one
/// frame), while the scheduler finishes the generation entirely
/// independently of the slow client.
#[test]
fn slow_reader_backpressure_bounds_write_queue_memory() {
    use std::sync::atomic::Ordering;
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 20);
    let cap = 512usize;
    let (addr, m) = boot_cfg(
        w,
        ServerCfg {
            write_queue_cap: cap,
            ..Default::default()
        },
    );
    let mut slow = Client::connect(&addr.to_string()).unwrap();
    let mut req = generate_req(&[1, 2, 3], 100);
    add_fields(&mut req, vec![("stream", Json::Bool(true))]);
    slow.send(&req).unwrap();
    // ...and read NOTHING while the whole generation runs server-side
    wait_until(
        || m.requests_completed.load(Ordering::Relaxed) >= 1,
        "scheduler to finish despite the unread stream",
    );
    let peak = m.write_queue_peak_bytes.load(Ordering::Relaxed) as usize;
    assert!(
        peak <= cap + 1024,
        "write queue grew past its cap + one frame: peak {peak} bytes (cap {cap})"
    );
    assert!(
        m.stream_tokens_sent.load(Ordering::Relaxed) > 0,
        "token frames should have been flowing"
    );
    // the stream is still complete and ordered once the reader catches up
    let mut streamed = Vec::new();
    let fin = loop {
        let frame = slow.read_reply().unwrap();
        match frame.get("event").and_then(|e| e.as_str()) {
            Some("token") => {
                streamed.push(frame.get("token").unwrap().as_u64().unwrap() as u32)
            }
            _ => break frame,
        }
    };
    assert_eq!(fin.get("finish").unwrap().as_str(), Some("length"));
    let final_tokens: Vec<u32> = fin
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|v| v.as_u64().map(|t| t as u32))
        .collect();
    assert_eq!(streamed, final_tokens, "backpressure must never drop frames");
    assert_eq!(streamed.len(), 100);
}

/// Cancelling from a second connection mid-stream closes the stream with
/// `"finish":"cancelled"` and the token frames already emitted match the
/// final object's tokens exactly.
#[test]
fn mid_stream_cancel_closes_the_stream_as_cancelled() {
    let mut cfg = ModelConfig::tiny_mha();
    cfg.max_seq_len = 2048; // room for a generation long enough to out-race
    let w = ModelWeights::init_vanilla(&cfg, 21);
    let (addr, _m) = boot_cfg(w, ServerCfg::default());
    let mut a = Client::connect(&addr.to_string()).unwrap();
    let mut b = Client::connect(&addr.to_string()).unwrap();
    let mut req = generate_req(&[1, 2, 3], 1500);
    add_fields(
        &mut req,
        vec![("stream", Json::Bool(true)), ("id", Json::num(55.0))],
    );
    a.send(&req).unwrap();
    // guarantee we are mid-stream: at least one token frame arrived
    let first = a.read_reply().unwrap();
    assert_eq!(first.get("event").and_then(|e| e.as_str()), Some("token"));
    let r = b
        .call(&Json::parse(r#"{"op":"cancel","id":55}"#).unwrap())
        .unwrap();
    assert_eq!(r.get("cancelled").unwrap().as_bool(), Some(true));
    let mut streamed = vec![first.get("token").unwrap().as_u64().unwrap() as u32];
    let fin = loop {
        let frame = a.read_reply().unwrap();
        match frame.get("event").and_then(|e| e.as_str()) {
            Some("token") => {
                streamed.push(frame.get("token").unwrap().as_u64().unwrap() as u32)
            }
            _ => break frame,
        }
    };
    assert_eq!(fin.get("finish").unwrap().as_str(), Some("cancelled"));
    assert_eq!(fin.get("id").unwrap().as_u64(), Some(55));
    let final_tokens: Vec<u32> = fin
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|v| v.as_u64().map(|t| t as u32))
        .collect();
    assert_eq!(streamed, final_tokens);
    assert!(
        final_tokens.len() < 1500,
        "the cancel should have landed mid-generation"
    );
    // the connection survives the cancelled stream
    let pong = a.call(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
}

/// With the admission queue at its depth limit, further generates shed
/// immediately with the structured `{"ok":false,"error":"overloaded"}`
/// reply instead of queueing without bound.
#[test]
fn load_shed_replies_overloaded_at_queue_depth() {
    use std::sync::atomic::Ordering;
    let mut cfg = ModelConfig::tiny_mha();
    cfg.max_seq_len = 2048;
    let w = ModelWeights::init_vanilla(&cfg, 22);
    let (addr, m) = boot_cfg(
        w,
        ServerCfg {
            queue_depth: 1,
            ..Default::default()
        },
    );
    // occupy the single admission slot with a long-running request
    let mut a = Client::connect(&addr.to_string()).unwrap();
    let mut long = generate_req(&[1, 2, 3], 1500);
    add_fields(&mut long, vec![("id", Json::num(66.0))]);
    a.send(&long).unwrap();
    wait_until(
        || m.requests_admitted.load(Ordering::Relaxed) >= 1,
        "the long request to be admitted",
    );
    // a second client's generate now sheds instead of queueing
    let mut b = Client::connect(&addr.to_string()).unwrap();
    let shed = b.call(&generate_req(&[4, 5], 3)).unwrap();
    assert_eq!(shed.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(shed.get("error").unwrap().as_str(), Some("overloaded"));
    assert!(m.requests_shed.load(Ordering::Relaxed) >= 1);
    // control ops are never shed
    let pong = b.call(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
    // free the slot (and the compute) so the test tears down fast
    let r = b
        .call(&Json::parse(r#"{"op":"cancel","id":66}"#).unwrap())
        .unwrap();
    assert_eq!(r.get("cancelled").unwrap().as_bool(), Some(true));
    let fin = a.read_reply().unwrap();
    assert_eq!(fin.get("finish").unwrap().as_str(), Some("cancelled"));
    // with the slot free again, generates are admitted once more
    let ok = b.call(&generate_req(&[4, 5], 3)).unwrap();
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
}

/// The per-client token bucket rejects a burst past `--rate-limit` with
/// the structured `rate_limited` error, without disturbing the connection.
#[test]
fn rate_limit_rejects_burst_with_structured_error() {
    use std::sync::atomic::Ordering;
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 23);
    // 0.2 ops/sec ⇒ burst of 1; a same-second second request must reject
    let (addr, m) = boot_cfg(
        w,
        ServerCfg {
            rate_limit: 0.2,
            ..Default::default()
        },
    );
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let first = c.call(&generate_req(&[1, 2], 2)).unwrap();
    assert_eq!(first.get("ok").unwrap().as_bool(), Some(true));
    let second = c.call(&generate_req(&[1, 2], 2)).unwrap();
    assert_eq!(second.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(second.get("error").unwrap().as_str(), Some("rate_limited"));
    assert!(m.requests_rate_limited.load(Ordering::Relaxed) >= 1);
    // non-generate ops are not rate limited
    let pong = c.call(&Json::obj(vec![("op", Json::str("ping"))])).unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
}

/// Streaming over the wire is byte-compatible with blocking serving: same
/// request, same tokens array serialization, tokens identical to a direct
/// engine run.
#[test]
fn streamed_generate_matches_blocking_and_engine() {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 24);
    let want = greedy_generate(&w, &[7, 8, 9], 6);
    let (addr, _m) = boot_cfg(w, ServerCfg::default());
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let blocking = c.call(&generate_req(&[7, 8, 9], 6)).unwrap();
    let (streamed, fin) = c.generate_streaming(&[7, 8, 9], 6).unwrap();
    assert_eq!(streamed, want);
    assert_eq!(
        fin.get("tokens").unwrap().to_string(),
        blocking.get("tokens").unwrap().to_string(),
        "streamed final object must serialize the same tokens byte-for-byte"
    );
    assert_eq!(fin.get("finish"), blocking.get("finish"));
    assert_eq!(fin.get("ok"), blocking.get("ok"));
}
