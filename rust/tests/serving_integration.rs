//! Full-stack serving integration (no artifacts required): tokenizer →
//! TCP server → coordinator → CPU engine → sampler, plus the weight-file
//! and surgery round-trips a deployment would perform.

use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{Coordinator, CpuEngine, Request, SchedulerCfg};
use skipless::model::{greedy_generate, weights_io, ModelWeights};
use skipless::server::{Client, Server};
use skipless::surgery::{transform, Options};
use skipless::tokenizer::Bpe;
use skipless::util::json::Json;
use std::sync::Arc;

fn boot_server(w: ModelWeights) -> std::net::SocketAddr {
    let coord = Coordinator::spawn(CpuEngine::new(w, 8, 32 << 20), SchedulerCfg::default());
    let server = Server::bind("127.0.0.1:0", coord).unwrap();
    let addr = server.local_addr();
    std::thread::spawn(move || {
        let _ = server.serve();
    });
    addr
}

#[test]
fn text_in_text_out_through_the_whole_stack() {
    let corpus = "the cat sat on the mat. the dog sat on the log. the cat and the dog sat.";
    let bpe = Bpe::train(corpus, 256 + 40);
    let mut cfg = ModelConfig::tiny_gqa();
    cfg.vocab_size = bpe.vocab_size().max(cfg.vocab_size);
    let w = ModelWeights::init_vanilla(&cfg, 7);
    let want = greedy_generate(&w, &bpe.encode("the cat"), 6);

    let addr = boot_server(w);
    let mut client = Client::connect(&addr.to_string()).unwrap();
    let got = client.generate(&bpe.encode("the cat"), 6).unwrap();
    assert_eq!(got, want);
    // decodes back to *some* bytes losslessly
    let text = bpe.decode_lossy(&got);
    assert!(!text.is_empty());
}

#[test]
fn merged_server_serves_identical_text() {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 8);
    let m = transform(&w, Variant::MergedQP, Options::default()).unwrap();
    let addr_v = boot_server(w);
    let addr_m = boot_server(m);
    let mut cv = Client::connect(&addr_v.to_string()).unwrap();
    let mut cm = Client::connect(&addr_m.to_string()).unwrap();
    for prompt in [vec![1u32, 2, 3], vec![200, 100], vec![42; 5]] {
        let a = cv.generate(&prompt, 7).unwrap();
        let b = cm.generate(&prompt, 7).unwrap();
        assert_eq!(a, b, "prompt {prompt:?}");
    }
}

#[test]
fn sampling_requests_over_the_wire_are_seed_deterministic() {
    let cfg = ModelConfig::tiny_mha();
    let addr = boot_server(ModelWeights::init_vanilla(&cfg, 9));
    let mut c = Client::connect(&addr.to_string()).unwrap();
    let req = Json::parse(
        r#"{"op":"generate","prompt":[4,5,6],"max_new_tokens":10,
            "temperature":0.9,"top_k":20,"top_p":0.9,"seed":1234}"#,
    )
    .unwrap();
    let r1 = c.call(&req).unwrap();
    let r2 = c.call(&req).unwrap();
    assert_eq!(r1.get("tokens"), r2.get("tokens"), "same seed, same stream");
    // different seed → (almost surely) different stream
    let req2 = Json::parse(
        r#"{"op":"generate","prompt":[4,5,6],"max_new_tokens":10,
            "temperature":0.9,"top_k":20,"top_p":0.9,"seed":99}"#,
    )
    .unwrap();
    let r3 = c.call(&req2).unwrap();
    assert_ne!(r1.get("tokens"), r3.get("tokens"));
}

#[test]
fn surgery_file_roundtrip_serves_equivalently() {
    // init → save → surgery → save → load → serve: the deployment path.
    let dir = std::env::temp_dir().join("skipless_serving_it");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = ModelConfig::tiny_mqa();
    let w = ModelWeights::init_vanilla(&cfg, 10);
    let vanilla_path = dir.join("m.swt");
    weights_io::save(&w, &vanilla_path).unwrap();

    let loaded = weights_io::load(&vanilla_path).unwrap();
    let merged = transform(&loaded, Variant::MergedQP, Options::default()).unwrap();
    let merged_path = dir.join("m.merged.swt");
    weights_io::save(&merged, &merged_path).unwrap();

    let served = weights_io::load(&merged_path).unwrap();
    assert_eq!(served.variant, Variant::MergedQP);
    let want = greedy_generate(&w, &[3, 1, 4], 6);
    let got = greedy_generate(&served, &[3, 1, 4], 6);
    assert_eq!(got, want, "deployment roundtrip changed the function");
}

#[test]
fn concurrent_load_with_metrics() {
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 11);
    let coord = Coordinator::spawn(CpuEngine::new(w, 8, 32 << 20), SchedulerCfg::default());
    let coord = Arc::new(coord);
    let handles: Vec<_> = (0..12u64)
        .map(|i| {
            let c = Arc::clone(&coord);
            std::thread::spawn(move || {
                let r = c.generate(Request::greedy(i, vec![(i % 7 + 1) as u32, 2], 5));
                assert_eq!(r.tokens.len(), 5);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    use std::sync::atomic::Ordering;
    let m = coord.metrics();
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), 12);
    assert_eq!(m.tokens_decoded.load(Ordering::Relaxed), 60);
    assert!(m.e2e.count() == 12);
}
