//! The zero-allocation steady-state contract, asserted forever.
//!
//! A counting `#[global_allocator]` wraps `System` and tallies every
//! allocation in the process. After a short warmup (which is allowed — and
//! expected — to grow the step arena to the workload's high-water shape),
//! every steady-state engine step must perform **exactly zero** heap
//! allocations: plain batched decode, speculative verify, and decode after
//! chunked prefill, across {f32, int8} weights × {CpuEngine, 2-way
//! tensor-parallel ShardedEngine}, with sampling (`sample_with` on warmed
//! [`SamplerScratch`]) measured inside the same window.
//!
//! Also pinned here, as allocation regressions rather than output checks:
//! the former hot-path clones — `Weight::proj` with an absent projection
//! used to clone the whole input, `Weight::to_f32` on an f32 weight used to
//! clone the matrix — must stay borrow-only (`Cow::Borrowed`).
//!
//! Harness notes: the counters are process-global, so every test takes one
//! mutex (`gate`) — a measured window overlapping another test's
//! allocations would count them. `SKIPLESS_THREADS=1` is set before any
//! engine exists so both engines take their inline serial paths (worker
//! threads would otherwise allocate stack/channel state out of band; the
//! serial path is also the one whose scratch the arena owns). Block size is
//! 64 tokens and prompts are short, so measured steps never cross a KV
//! block boundary — block *grants* are prefill-time work, not steady state.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Mutex, MutexGuard};

use skipless::config::ModelConfig;
use skipless::coordinator::{
    ChunkInput, CpuEngine, DecodeInput, Engine, ShardedEngine, StepOut, VerifyInput, VerifyOut,
};
use skipless::model::{quantize, ModelWeights, Weight};
use skipless::sampler::{argmax, sample_with, SamplerCfg, SamplerScratch};
use skipless::tensor::Mat;
use skipless::util::rng::Xoshiro256;

// ---------------------------------------------------------------------------
// Counting allocator
// ---------------------------------------------------------------------------

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(l.size() as u64, Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(l.size() as u64, Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        // a growing realloc is an allocation event for this contract
        ALLOCS.fetch_add(1, Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Relaxed);
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

static GATE: Mutex<()> = Mutex::new(());

/// Serialize tests (global counters) and force the serial compute paths.
fn gate() -> MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    std::env::set_var("SKIPLESS_THREADS", "1");
    g
}

/// `(allocations, bytes, result)` attributable to `f`.
fn count<R>(f: impl FnOnce() -> R) -> (u64, u64, R) {
    let a0 = ALLOCS.load(Relaxed);
    let b0 = ALLOC_BYTES.load(Relaxed);
    let r = f();
    (ALLOCS.load(Relaxed) - a0, ALLOC_BYTES.load(Relaxed) - b0, r)
}

/// The harness must be able to see allocations at all, or every zero below
/// is vacuous.
#[test]
fn counting_allocator_is_wired() {
    let _g = gate();
    let (a, b, v) = count(|| Vec::<u64>::with_capacity(100));
    assert!(a >= 1, "allocation not observed");
    assert!(b >= 800, "allocation bytes not observed (got {b})");
    drop(v);
}

// ---------------------------------------------------------------------------
// Matrix cells
// ---------------------------------------------------------------------------

const BLOCK_TOKENS: usize = 64;
const BUDGET: usize = 16 << 20;
const WARMUP: usize = 3;
const MEASURE: usize = 4;

fn weights(int8: bool) -> ModelWeights {
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 4242);
    if int8 {
        quantize(&w)
    } else {
        w
    }
}

fn sampler_cfg() -> SamplerCfg {
    // temperature + top-k + top-p: the full dist_into pipeline, including
    // the partition-based top-k path, runs inside the measured window
    SamplerCfg { temperature: 0.9, top_k: 16, top_p: 0.95 }
}

/// Batched plain decode: prefill two prompts, warm up, then assert every
/// further fused step AND both sampler draws allocate nothing. A twin
/// engine stepping through the allocating `step_batch` API pins
/// bit-identity of the `_into` path on the same token stream.
fn plain_decode_cell<E: Engine, T: Engine>(mut engine: E, mut twin: T, tag: &str) {
    engine.plan_alloc(4, 3);
    let vocab = 256u32;
    let p0: Vec<u32> = (0..9).map(|i| (i * 13 + 5) % vocab).collect();
    let p1: Vec<u32> = (0..7).map(|i| (i * 29 + 3) % vocab).collect();
    let (s0, l0) = engine.prefill(&p0).unwrap();
    let (s1, l1) = engine.prefill(&p1).unwrap();
    let (t0, tl0) = twin.prefill(&p0).unwrap();
    let (t1, tl1) = twin.prefill(&p1).unwrap();
    assert_eq!(l0, tl0, "{tag}: prefill logits diverge");
    assert_eq!(l1, tl1, "{tag}: prefill logits diverge");

    let cfg = sampler_cfg();
    let mut rng = Xoshiro256::seed_from_u64(0xa110c);
    let mut scratch = SamplerScratch::new();
    let mut out = StepOut::default();
    let mut toks = [argmax(&l0), argmax(&l1)];

    for step in 0..WARMUP + MEASURE {
        let inputs =
            [DecodeInput { seq: s0, token: toks[0] }, DecodeInput { seq: s1, token: toks[1] }];
        if step < WARMUP {
            engine.step_batch_into(&inputs, &[], &mut out).unwrap();
        } else {
            let (a, b, r) = count(|| engine.step_batch_into(&inputs, &[], &mut out));
            r.unwrap();
            assert_eq!(
                (a, b),
                (0, 0),
                "{tag}: step {step} allocated {a} times / {b} bytes in steady state"
            );
        }
        // allocating twin on the same tokens: rows must match to the bit
        let twin_inputs =
            [DecodeInput { seq: t0, token: toks[0] }, DecodeInput { seq: t1, token: toks[1] }];
        let tr = twin.step_batch(&twin_inputs, &[]).unwrap();
        for (r, row) in tr.decode_logits.iter().enumerate() {
            let bits: Vec<u32> = out.decode_logits.row(r).iter().map(|v| v.to_bits()).collect();
            let want: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits, want, "{tag}: step {step} row {r} diverges from step_batch");
        }
        for (r, t) in toks.iter_mut().enumerate() {
            let row = out.decode_logits.row(r);
            if step < WARMUP {
                *t = sample_with(row, &cfg, &mut rng, &mut scratch);
            } else {
                let (a, _, tok) = count(|| sample_with(row, &cfg, &mut rng, &mut scratch));
                assert_eq!(a, 0, "{tag}: sampler allocated at step {step} row {r}");
                *t = tok;
            }
        }
    }

    let stats = engine.alloc_stats().expect("arena engines report alloc stats");
    assert!(stats.arena_bytes > 0, "{tag}: arena not warm after decode");
    engine.release(s0);
    engine.release(s1);
    twin.release(t0);
    twin.release(t1);
}

#[test]
fn plain_decode_steady_state_allocates_zero() {
    let _g = gate();
    for int8 in [false, true] {
        let w = weights(int8);
        plain_decode_cell(
            CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET),
            CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET),
            if int8 { "cpu/int8" } else { "cpu/f32" },
        );
        plain_decode_cell(
            ShardedEngine::new(w.clone(), 2, BLOCK_TOKENS, BUDGET).unwrap(),
            ShardedEngine::new(w, 2, BLOCK_TOKENS, BUDGET).unwrap(),
            if int8 { "tp2/int8" } else { "tp2/f32" },
        );
    }
}

/// Speculative steady state: a widened verify step over a fixed draft,
/// rolled back each round (the reject-everything worst case, so positions
/// never advance and every round replays the same shapes). After warmup the
/// verify step itself must allocate nothing; the rollback `truncate` runs
/// outside the window (block frees are not steady-state decode work).
fn spec_verify_cell<E: Engine>(mut engine: E, tag: &str) {
    engine.plan_alloc(4, 3);
    let prompt = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
    let (seq, _) = engine.prefill(&prompt).unwrap();
    let base_len = prompt.len();
    let inputs = [VerifyInput { seq, tokens: vec![7, 8, 9, 10] }];
    let mut out = VerifyOut::default();

    let mut golden: Vec<Vec<u32>> = Vec::new();
    for round in 0..WARMUP + MEASURE {
        if round < WARMUP {
            engine.verify_batch_into(&inputs, &mut out).unwrap();
        } else {
            let (a, b, r) = count(|| engine.verify_batch_into(&inputs, &mut out));
            r.unwrap();
            assert_eq!(
                (a, b),
                (0, 0),
                "{tag}: verify round {round} allocated {a} times / {b} bytes"
            );
        }
        // every round replays the same positions with the same tokens, so
        // the rows must be byte-stable across rounds — rollback is clean
        let rows: Vec<Vec<u32>> = (0..inputs[0].tokens.len())
            .map(|r| out.rows.row(out.row0[0] + r).iter().map(|v| v.to_bits()).collect())
            .collect();
        if round == 0 {
            golden = rows;
        } else {
            assert_eq!(golden, rows, "{tag}: verify rows drifted at round {round}");
        }
        engine.truncate(seq, base_len).unwrap();
    }
    engine.release(seq);
}

#[test]
fn speculative_verify_steady_state_allocates_zero() {
    let _g = gate();
    for int8 in [false, true] {
        let w = weights(int8);
        spec_verify_cell(
            CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET),
            if int8 { "cpu/int8" } else { "cpu/f32" },
        );
        spec_verify_cell(
            ShardedEngine::new(w, 2, BLOCK_TOKENS, BUDGET).unwrap(),
            if int8 { "tp2/int8" } else { "tp2/f32" },
        );
    }
}

/// Chunked-prefill admission, then steady decode: the chunk-carrying steps
/// may allocate (chunk completions return owned rows by contract — they are
/// admission work, not steady state); the pure decode steps that follow
/// must not.
fn chunked_then_decode_cell<E: Engine>(mut engine: E, tag: &str) {
    engine.plan_alloc(8, 0);
    let vocab = 256u32;
    let prompt: Vec<u32> = (0..11).map(|i| (i * 7 + 2) % vocab).collect();
    let (seq, filled) = engine.prefill_begin(&prompt).unwrap();
    assert_eq!(filled, 0, "{tag}: cold start");
    let mut out = StepOut::default();
    let mut last = None;
    for chunk in [&prompt[0..3], &prompt[3..8], &prompt[8..11]] {
        let chunks = [ChunkInput { seq, tokens: chunk.to_vec() }];
        engine.step_batch_into(&[], &chunks, &mut out).unwrap();
        if let Some(row) = out.chunk_logits.first().and_then(|c| c.as_deref()) {
            last = Some(argmax(row));
        }
    }
    let mut tok = last.expect("final chunk completes the prompt");

    for step in 0..WARMUP + MEASURE {
        let inputs = [DecodeInput { seq, token: tok }];
        if step < WARMUP {
            engine.step_batch_into(&inputs, &[], &mut out).unwrap();
        } else {
            let (a, b, r) = count(|| engine.step_batch_into(&inputs, &[], &mut out));
            r.unwrap();
            assert_eq!(
                (a, b),
                (0, 0),
                "{tag}: post-chunk decode step {step} allocated {a} times / {b} bytes"
            );
        }
        tok = argmax(out.decode_logits.row(0));
    }
    engine.release(seq);
}

#[test]
fn decode_after_chunked_prefill_allocates_zero() {
    let _g = gate();
    for int8 in [false, true] {
        let w = weights(int8);
        chunked_then_decode_cell(
            CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET),
            if int8 { "cpu/int8" } else { "cpu/f32" },
        );
        chunked_then_decode_cell(
            ShardedEngine::new(w, 2, BLOCK_TOKENS, BUDGET).unwrap(),
            if int8 { "tp2/int8" } else { "tp2/f32" },
        );
    }
}

/// The arena's growth gauge must agree with the allocator: once warmed, a
/// long decode run records zero growth events past the warmup high water.
#[test]
fn growth_gauge_stays_flat_in_steady_state() {
    let _g = gate();
    let w = weights(false);
    let mut engine = CpuEngine::new(w, BLOCK_TOKENS, BUDGET);
    engine.plan_alloc(2, 0);
    let (seq, l0) = engine.prefill(&[5, 3, 8, 250, 11]).unwrap();
    let mut tok = argmax(&l0);
    let mut out = StepOut::default();
    for _ in 0..WARMUP {
        engine.step_batch_into(&[DecodeInput { seq, token: tok }], &[], &mut out).unwrap();
        tok = argmax(out.decode_logits.row(0));
    }
    let g0 = engine.alloc_stats().unwrap().growth_events;
    for _ in 0..2 * MEASURE {
        engine.step_batch_into(&[DecodeInput { seq, token: tok }], &[], &mut out).unwrap();
        tok = argmax(out.decode_logits.row(0));
    }
    let s1 = engine.alloc_stats().unwrap();
    assert_eq!(s1.growth_events, g0, "arena grew after warmup");
    assert!(s1.arena_bytes > 0);
    engine.release(seq);
}

// ---------------------------------------------------------------------------
// Satellite regressions: the former hot-path clones
// ---------------------------------------------------------------------------

/// `Weight::proj` with an absent projection used to clone the entire input
/// matrix (and `Weight::to_f32` on f32 weights cloned the weight). Both are
/// borrow-only now; this pins it at the allocator level.
#[test]
fn weight_proj_identity_and_f32_view_do_not_allocate() {
    let _g = gate();
    let mut rng = Xoshiro256::seed_from_u64(0xc10e);
    let x = Mat::randn(6, 64, 0.5, &mut rng);
    let wf = Weight::F32(Mat::randn(64, 64, 0.05, &mut rng));

    let (a, b, cow) = count(|| Weight::proj(&x, &None));
    assert!(matches!(cow, std::borrow::Cow::Borrowed(_)), "identity proj must borrow");
    assert_eq!((a, b), (0, 0), "identity proj allocated ({a} allocs, {b} bytes)");
    assert_eq!(cow.as_slice().as_ptr(), x.as_slice().as_ptr(), "borrow must alias the input");

    let (a, b, cow) = count(|| wf.to_f32());
    assert!(matches!(cow, std::borrow::Cow::Borrowed(_)), "f32 view must borrow");
    assert_eq!((a, b), (0, 0), "to_f32 on F32 allocated ({a} allocs, {b} bytes)");
}

/// `sample_with` on a warmed scratch is allocation-free across every
/// sampler mode (greedy short-circuit, plain temperature, top-k partition,
/// nucleus truncation, combined).
#[test]
fn sampler_modes_allocate_zero_after_warmup() {
    let _g = gate();
    let mut rng = Xoshiro256::seed_from_u64(0x5a3);
    let logits = Mat::randn(1, 256, 1.2, &mut rng);
    let row = logits.row(0);
    let modes = [
        SamplerCfg { temperature: 0.0, top_k: 0, top_p: 1.0 },
        SamplerCfg { temperature: 1.0, top_k: 0, top_p: 1.0 },
        SamplerCfg { temperature: 0.8, top_k: 12, top_p: 1.0 },
        SamplerCfg { temperature: 0.8, top_k: 0, top_p: 0.7 },
        SamplerCfg { temperature: 0.8, top_k: 40, top_p: 0.9 },
    ];
    let mut scratch = SamplerScratch::new();
    // warmup: largest candidate table first, then every mode once
    sample_with(row, &modes[1], &mut rng, &mut scratch);
    for cfg in &modes {
        sample_with(row, cfg, &mut rng, &mut scratch);
    }
    for (i, cfg) in modes.iter().enumerate() {
        for draw in 0..8 {
            let (a, _, _) = count(|| sample_with(row, cfg, &mut rng, &mut scratch));
            assert_eq!(a, 0, "mode {i} draw {draw} allocated");
        }
    }
}
