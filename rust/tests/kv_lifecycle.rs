//! KV-block lifecycle, end to end: prefix sharing and swap-style
//! preemption must be *invisible* in the token streams (byte-identical to
//! unshared / unpressured runs) while visibly saving work in the metrics.
//!
//! This is the integration-level counterpart of the unit tests in
//! `kvcache` and `coordinator` — whole scheduler runs, mixed workloads,
//! and the serving metrics as the observable.

use skipless::config::ModelConfig;
use skipless::coordinator::{CpuEngine, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::CacheOpts;
use skipless::metrics::Metrics;
use skipless::model::{greedy_generate, ModelWeights};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Batch of requests sharing a long "system prompt" prefix with distinct
/// user suffixes, plus a couple of unrelated prompts mixed in.
fn shared_prefix_workload(vocab: u32) -> Vec<Vec<u32>> {
    let system: Vec<u32> = (0..24).map(|i| (i * 5 + 3) % vocab).collect();
    let mut prompts: Vec<Vec<u32>> = (0..8)
        .map(|i| {
            let mut p = system.clone();
            p.extend([(i * 7 + 1) % vocab, (i * 11 + 2) % vocab]);
            p
        })
        .collect();
    prompts.push((0..10).map(|i| (i * 17 + 9) % vocab).collect());
    prompts.push((0..5).map(|i| (i * 23 + 4) % vocab).collect());
    prompts
}

fn run_all(
    w: &ModelWeights,
    prompts: &[Vec<u32>],
    block_tokens: usize,
    budget: usize,
    opts: CacheOpts,
) -> (Vec<Vec<u32>>, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let mut s = Scheduler::new(
        CpuEngine::with_cache_opts(w.clone(), block_tokens, budget, opts),
        SchedulerCfg {
            max_running: 16,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    for (i, p) in prompts.iter().enumerate() {
        s.submit(Request::greedy(i as u64, p.clone(), 6));
    }
    let mut done = s.run_to_completion();
    done.sort_by_key(|r| r.id);
    (done.into_iter().map(|r| r.tokens).collect(), metrics)
}

#[test]
fn prefix_sharing_skips_prefill_without_changing_tokens() {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 90);
    let prompts = shared_prefix_workload(cfg.vocab_size as u32);

    let on = CacheOpts::default();
    let off = CacheOpts {
        prefix_sharing: false,
        ..Default::default()
    };
    let (tok_on, m_on) = run_all(&w, &prompts, 8, 8 << 20, on);
    let (tok_off, m_off) = run_all(&w, &prompts, 8, 8 << 20, off);

    assert_eq!(tok_on, tok_off, "prefix sharing changed generated tokens");
    // ... and against the model oracle, sharing or not
    for (p, t) in prompts.iter().zip(&tok_on) {
        assert_eq!(t, &greedy_generate(&w, p, 6), "prompt {p:?}");
    }

    let saved = m_on.kv_prefix_tokens_saved.load(Ordering::Relaxed);
    let computed_on = m_on.tokens_prefilled.load(Ordering::Relaxed);
    let computed_off = m_off.tokens_prefilled.load(Ordering::Relaxed);
    assert!(saved > 0, "no prefill tokens were saved");
    assert!(m_on.prefix_hit_rate() > 0.0, "prefix-hit rate not reported");
    assert_eq!(m_off.kv_prefix_tokens_saved.load(Ordering::Relaxed), 0);
    assert_eq!(
        computed_on + saved,
        computed_off,
        "every prompt token must be either computed or saved"
    );
    // the shared 24-token system prompt spans 3 full blocks of 8; seven
    // warm requests should each skip them
    assert!(saved >= 7 * 24, "saved {saved}, expected >= 168");
}

#[test]
fn swap_preemption_resumes_byte_identical_streams() {
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 91);
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| (0..7).map(|j| ((i * 41 + j * 13 + 5) % 250) as u32).collect())
        .collect();

    // roomy reference
    let (want, m_roomy) = run_all(&w, &prompts, 4, 8 << 20, CacheOpts::default());
    assert_eq!(m_roomy.kv_swap_outs.load(Ordering::Relaxed), 0);

    // pool of 8 blocks × 4 tokens: 4 seqs × ceil(13/4)=4 blocks don't fit
    let bytes_per_block = 2 * cfg.e() * cfg.n_layers * 4 * 4;
    let (got, m_tight) = run_all(&w, &prompts, 4, 8 * bytes_per_block, CacheOpts::default());

    assert_eq!(got, want, "preemption pressure changed token streams");
    assert!(
        m_tight.kv_swap_outs.load(Ordering::Relaxed) > 0,
        "tight pool never swapped — test lost its bite"
    );
    assert_eq!(
        m_tight.kv_swap_outs.load(Ordering::Relaxed),
        m_tight.kv_swap_ins.load(Ordering::Relaxed),
        "a swapped sequence was never resumed"
    );
    assert_eq!(m_tight.requests_completed.load(Ordering::Relaxed), 4);
}

#[test]
fn pressure_plus_sharing_compose() {
    // Tight pool AND shared prefixes: eviction may reclaim cached prefix
    // blocks at any time; correctness must survive the interaction.
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 92);
    let prompts = shared_prefix_workload(cfg.vocab_size as u32);

    let (want, _) = run_all(&w, &prompts, 4, 8 << 20, CacheOpts::default());
    let bytes_per_block = 2 * cfg.e() * cfg.n_layers * 4 * 4;
    // ~14 blocks: enough to admit (prompt 26 → 7 blocks) but far below the
    // ~80 blocks the full workload would like
    let (got, m) = run_all(&w, &prompts, 4, 14 * bytes_per_block, CacheOpts::default());
    assert_eq!(got, want, "pressure + sharing changed outputs");
    assert_eq!(m.requests_completed.load(Ordering::Relaxed), prompts.len() as u64);
}
