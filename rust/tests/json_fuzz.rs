//! Fuzz-style tests for the hand-rolled `util::json` parser.
//!
//! The parser sits on the serving wire (every TCP request line) and under
//! the weight-file/config loaders, so its two contracts are load-bearing:
//!
//! 1. **parse ∘ serialize = identity** for every value the writer can
//!    produce (compact and pretty).
//! 2. **Malformed input must error, never panic** — a panicking parser is
//!    a remote crash. Random byte strings, truncations, and single-byte
//!    mutations of valid documents all have to come back as `Result`.
//!
//! Driven by the in-tree Xoshiro PRNG (no proptest in the offline image);
//! failing cases reproduce by fixing `CASE_SEED`.

use skipless::util::json::Json;
use skipless::util::rng::Xoshiro256;
use std::collections::BTreeMap;

const CASE_SEED: u64 = 0xFADED;

/// Random JSON value, depth-bounded. Numbers are drawn from integers,
/// dyadic fractions, and scaled normals — all round-trip exactly through
/// Rust's shortest-representation float printing.
fn random_value(rng: &mut Xoshiro256, depth: usize) -> Json {
    let pick = if depth == 0 { rng.next_below(4) } else { rng.next_below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.next_below(2) == 1),
        2 => {
            let n = match rng.next_below(3) {
                0 => rng.next_below(1 << 53) as f64 - (1u64 << 52) as f64,
                1 => rng.next_below(1 << 20) as f64 / 8.0,
                _ => rng.next_normal() * 1e6,
            };
            Json::Num(n)
        }
        3 => Json::Str(random_string(rng)),
        4 => {
            let len = rng.next_below(5) as usize;
            Json::Arr((0..len).map(|_| random_value(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.next_below(5) as usize;
            let mut map = BTreeMap::new();
            for _ in 0..len {
                map.insert(random_string(rng), random_value(rng, depth - 1));
            }
            Json::Obj(map)
        }
    }
}

/// Strings mixing ASCII, escapes-in-waiting, and multibyte UTF-8.
fn random_string(rng: &mut Xoshiro256) -> String {
    const POOL: &[char] = &[
        'a', 'b', 'z', '0', '9', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{0008}', '\u{000C}',
        '\u{0001}', 'é', 'ß', '→', '中', '😀', '\u{10FFFF}',
    ];
    let len = rng.next_below(12) as usize;
    (0..len)
        .map(|_| POOL[rng.next_below(POOL.len() as u64) as usize])
        .collect()
}

#[test]
fn fuzz_parse_serialize_identity() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED);
    for case in 0..500 {
        let v = random_value(&mut rng, 4);
        let compact = v.to_string();
        let back = Json::parse(&compact)
            .unwrap_or_else(|e| panic!("case {case}: rejected own output {compact:?}: {e}"));
        assert_eq!(back, v, "case {case}: compact roundtrip changed the value");
        let pretty = v.to_string_pretty();
        let back = Json::parse(&pretty)
            .unwrap_or_else(|e| panic!("case {case}: rejected pretty output: {e}"));
        assert_eq!(back, v, "case {case}: pretty roundtrip changed the value");
    }
}

#[test]
fn fuzz_random_bytes_never_panic() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 1);
    for _case in 0..2000 {
        let len = rng.next_below(64) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
        let text = String::from_utf8_lossy(&bytes);
        // must return Ok or Err — a panic fails this test
        let _ = Json::parse(&text);
    }
}

/// Bias the fuzz toward *almost*-valid input: JSON-ish byte soup drawn from
/// structural characters, then mutations and truncations of genuinely
/// valid documents — the inputs most likely to trip a hand-rolled parser.
#[test]
fn fuzz_jsonish_soup_and_mutations_never_panic() {
    const SOUP: &[u8] = b"{}[]\",:0123456789.eE+-tfn\\u \n";
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 2);
    for _case in 0..2000 {
        let len = rng.next_below(48) as usize;
        let bytes: Vec<u8> = (0..len)
            .map(|_| SOUP[rng.next_below(SOUP.len() as u64) as usize])
            .collect();
        let _ = Json::parse(&String::from_utf8_lossy(&bytes));
    }
    for case in 0..500 {
        let v = random_value(&mut rng, 3);
        let mut bytes = v.to_string().into_bytes();
        if bytes.is_empty() {
            continue;
        }
        match rng.next_below(3) {
            0 => {
                // single-byte mutation
                let i = rng.next_below(bytes.len() as u64) as usize;
                bytes[i] = rng.next_below(256) as u8;
            }
            1 => {
                // truncation
                bytes.truncate(rng.next_below(bytes.len() as u64) as usize);
            }
            _ => {
                // duplication (unbalances the structure)
                let extra = bytes.clone();
                bytes.extend(extra);
            }
        }
        let text = String::from_utf8_lossy(&bytes);
        let _ = Json::parse(&text); // Ok or Err, never a panic
        let _ = case;
    }
}

#[test]
fn malformed_corpus_errors_cleanly() {
    // Every entry is invalid JSON; each must produce Err (not Ok, not a
    // panic). Grown from bugs this grammar class historically attracts:
    // unterminated containers/strings, bad escapes, lone surrogates,
    // trailing garbage, truncated literals.
    let corpus: &[&str] = &[
        "",
        "   ",
        "{",
        "}",
        "[",
        "]",
        "[1,",
        "[1 2]",
        "[1,]",
        "{\"a\"}",
        "{\"a\" 1}",
        "{\"a\":}",
        "{\"a\":1,}",
        "{a:1}",
        "{\"a\":1 \"b\":2}",
        "\"",
        "\"abc",
        "\"\\\"",
        "\"\\x\"",
        "\"\\u12\"",
        "\"\\ud800\"",
        "\"\\udc00\"",
        "\"\\ud800\\u0041\"",
        "tru",
        "truex",
        "nul",
        "+1",
        "--1",
        "1 2",
        "1,",
        "{}{}",
        "\u{0007}",
    ];
    for src in corpus {
        assert!(
            Json::parse(src).is_err(),
            "parser accepted malformed input {src:?}"
        );
    }
}
