//! Tensor-parallel sharding must be *invisible*: a `ShardedEngine` over N
//! workers has to produce logits BYTE-identical (`assert_eq!` on the f32
//! vectors — no tolerance) to a single `CpuEngine` over the same weights,
//! across {f32, int8} weights × {MHA, GQA, MQA} head layouts × {plain
//! decode, speculative verify/rollback, chunked prefill} × {2, 4} workers.
//!
//! Why exact equality is attainable at all: the shards own disjoint
//! KV-head groups, so every GEMM is column-sliced (bit-exact — each output
//! element's k-accumulation never mixes columns), RoPE and attention are
//! per-head, and the joins are order-fixed concatenations followed by a
//! full-width FFN on the host — never a floating-point sum-reduce. See
//! DESIGN.md §Sharding and `coordinator::sharded`.
//!
//! The data-parallel mode trades that strict identity for independence:
//! replicas are whole engines, so each stream is identical to a
//! single-engine run by construction; what the test checks there is the
//! router — repeated prompts must land on the replica that cached their
//! prefix.

use skipless::config::ModelConfig;
use skipless::coordinator::{
    ChunkInput, Coordinator, CpuEngine, DecodeInput, Engine, Request, SchedulerCfg, ShardedEngine,
    VerifyInput,
};
use skipless::kvcache::CacheOpts;
use skipless::model::{greedy_generate, quantize, ModelWeights};
use std::sync::atomic::Ordering;

const BLOCK_TOKENS: usize = 8;
const BUDGET: usize = 16 << 20;

fn argmax(row: &[f32]) -> u32 {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u32
}

/// GQA config that admits 4 TP workers (tiny_gqa has only 2 KV heads).
fn gqa_8h_4kv() -> ModelConfig {
    let mut cfg = ModelConfig::tiny_gqa();
    cfg.name = "tiny-gqa-4kv".into();
    cfg.n_kv_heads = 4;
    cfg
}

/// Prefill + greedy-decode `steps` tokens on a single engine and on an
/// N-way sharded engine, asserting byte equality at every position.
fn assert_decode_bit_identical(w: &ModelWeights, n_workers: usize, steps: usize) {
    let mut single = CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET);
    let mut sharded =
        ShardedEngine::new(w.clone(), n_workers, BLOCK_TOKENS, BUDGET).expect("shardable");
    let prompt: Vec<u32> = (0..11).map(|i| (i * 13 + 5) % w.cfg.vocab_size as u32).collect();
    let (s0, l0) = single.prefill(&prompt).unwrap();
    let (s1, l1) = sharded.prefill(&prompt).unwrap();
    assert_eq!(l0, l1, "prefill logits, {} workers", n_workers);
    let mut tok = argmax(&l0);
    for step in 0..steps {
        let r0 = single.decode_batch(&[DecodeInput { seq: s0, token: tok }]).unwrap();
        let r1 = sharded.decode_batch(&[DecodeInput { seq: s1, token: tok }]).unwrap();
        assert_eq!(r0, r1, "decode step {step}, {} workers", n_workers);
        tok = argmax(&r0[0]);
    }
    single.release(s0);
    sharded.release(s1);
}

#[test]
fn f32_decode_bit_identical_across_layouts_and_widths() {
    // MHA: 4 KV heads — divisible by 2 and 4
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_mha(), 301);
    assert_decode_bit_identical(&w, 2, 6);
    assert_decode_bit_identical(&w, 4, 6);
    // GQA at ratio 4:1 per shard
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 302);
    assert_decode_bit_identical(&w, 2, 6);
    // GQA with 4 KV heads takes 4 workers
    let w = ModelWeights::init_vanilla(&gqa_8h_4kv(), 303);
    assert_decode_bit_identical(&w, 4, 6);
}

#[test]
fn int8_decode_bit_identical() {
    // per-channel scales travel with their columns, so the int8 kernel
    // sees exactly the bytes the full matrix would use for those outputs
    let w = quantize(&ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 304));
    assert_decode_bit_identical(&w, 2, 6);
    let w = quantize(&ModelWeights::init_vanilla(&gqa_8h_4kv(), 305));
    assert_decode_bit_identical(&w, 4, 6);
}

#[test]
fn surgeried_weights_shard_bit_identically() {
    // MergedQP leaves q = None in every block; the shard must column-slice
    // the block input itself, exactly like the full engine does
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 306);
    let w = skipless::surgery::transform(
        &w,
        skipless::config::Variant::MergedQP,
        skipless::surgery::Options::default(),
    )
    .unwrap();
    assert_decode_bit_identical(&w, 2, 6);
}

#[test]
fn verify_batch_and_rollback_bit_identical() {
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_mha(), 307);
    let mut single = CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET);
    let mut sharded = ShardedEngine::new(w.clone(), 2, BLOCK_TOKENS, BUDGET).unwrap();
    assert!(sharded.supports_rollback());
    let prompt = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
    let (s0, _) = single.prefill(&prompt).unwrap();
    let (s1, _) = sharded.prefill(&prompt).unwrap();
    // widened verify on the sharded engine vs one-at-a-time on the single
    let draft = vec![7u32, 8, 9, 10];
    let rows1 = sharded
        .verify_batch(&[VerifyInput { seq: s1, tokens: draft.clone() }])
        .unwrap();
    let mut rows0 = Vec::new();
    for &t in &draft {
        let r = single.decode_batch(&[DecodeInput { seq: s0, token: t }]).unwrap();
        rows0.push(r.into_iter().next().unwrap());
    }
    assert_eq!(rows1[0], rows0, "verify rows vs sequential decode");
    // reject the tail on both, then re-decode: rollback must be clean
    single.truncate(s0, prompt.len() + 1).unwrap();
    sharded.truncate(s1, prompt.len() + 1).unwrap();
    let r0 = single.decode_batch(&[DecodeInput { seq: s0, token: 42 }]).unwrap();
    let r1 = sharded.decode_batch(&[DecodeInput { seq: s1, token: 42 }]).unwrap();
    assert_eq!(r0, r1, "post-rollback decode");
}

#[test]
fn chunked_prefill_bit_identical_to_monolithic() {
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 308);
    let mut single = CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET);
    let mut sharded = ShardedEngine::new(w.clone(), 2, BLOCK_TOKENS, BUDGET).unwrap();
    assert!(sharded.supports_chunked_prefill());
    let prompt: Vec<u32> = (0..11).map(|i| (i * 7 + 2) % 256).collect();
    let (s0, l0) = single.prefill(&prompt).unwrap();
    let (s1, filled) = sharded.prefill_begin(&prompt).unwrap();
    assert_eq!(filled, 0, "cold start");
    // uneven split exercises mid-block chunk boundaries
    let mut last = None;
    for chunk in [&prompt[0..3], &prompt[3..8], &prompt[8..11]] {
        let out = sharded
            .step_batch(&[], &[ChunkInput { seq: s1, tokens: chunk.to_vec() }])
            .unwrap();
        last = out.chunk_logits.into_iter().next().flatten();
    }
    assert_eq!(last.expect("final chunk completes the prompt"), l0);
    // and the sequences decode identically afterwards
    let r0 = single.decode_batch(&[DecodeInput { seq: s0, token: 17 }]).unwrap();
    let r1 = sharded.decode_batch(&[DecodeInput { seq: s1, token: 17 }]).unwrap();
    assert_eq!(r0, r1);
}

#[test]
fn non_dividing_worker_count_is_a_clean_config_error() {
    // MQA has one KV head: no TP split exists at all
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_mqa(), 309);
    let err = ShardedEngine::new(w, 2, BLOCK_TOKENS, BUDGET).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("divide n_kv_heads"), "{msg}");
    // 2 KV heads cannot split 4 ways
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 310);
    assert!(ShardedEngine::new(w, 4, BLOCK_TOKENS, BUDGET).is_err());
    // quantized KV pools carry full-width per-position metadata: rejected
    let w = ModelWeights::init_vanilla(&ModelConfig::tiny_gqa(), 311);
    let opts = CacheOpts {
        quantized: true,
        ..Default::default()
    };
    let err = ShardedEngine::with_cache_opts(w, 2, BLOCK_TOKENS, BUDGET, opts).unwrap_err();
    assert!(err.to_string().contains("f32 KV pool"), "{err}");
}

#[test]
fn sharded_engine_serves_through_the_coordinator() {
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 312);
    let want = greedy_generate(&w, &[2, 7, 1, 8], 8);
    let c = Coordinator::spawn(
        ShardedEngine::new(w.clone(), 2, BLOCK_TOKENS, BUDGET).unwrap(),
        SchedulerCfg::default(),
    );
    let resp = c.generate(Request::greedy(1, vec![2, 7, 1, 8], 8));
    assert_eq!(resp.tokens, want, "token-identical through the scheduler");
    // the scheduler mirrors the engine's shard stats into the gauges
    let m = c.metrics();
    assert_eq!(m.shard_workers.load(Ordering::Relaxed), 2);
    assert_eq!(m.shard_mode.load(Ordering::Relaxed), 1, "tp");
    assert!(m.shard_allreduce_calls.load(Ordering::Relaxed) > 0);
    assert!(m.shard_allreduce_bytes.load(Ordering::Relaxed) > 0);
    c.shutdown();
}

#[test]
fn sharded_target_with_int8_draft_speculates_token_identically() {
    let cfg = ModelConfig::tiny_mha();
    let w = ModelWeights::init_vanilla(&cfg, 313);
    let want = greedy_generate(&w, &[5, 3, 8], 8);
    let c = Coordinator::spawn_speculative(
        ShardedEngine::new(w.clone(), 2, BLOCK_TOKENS, BUDGET).unwrap(),
        CpuEngine::new(quantize(&w), BLOCK_TOKENS, BUDGET),
        SchedulerCfg {
            spec_k: 3,
            ..Default::default()
        },
    );
    let resp = c.generate(Request::greedy(1, vec![5, 3, 8], 8));
    assert_eq!(resp.tokens, want);
    assert!(c.metrics().spec_rounds.load(Ordering::Relaxed) > 0);
    c.shutdown();
}

#[test]
fn dp_router_reuses_the_replica_with_the_cached_prefix() {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 314);
    let c = Coordinator::spawn_replicated(
        |_| CpuEngine::new(w.clone(), BLOCK_TOKENS, BUDGET),
        2,
        BLOCK_TOKENS,
        SchedulerCfg::default(),
    );
    let prompt: Vec<u32> = (0..20).map(|i| (i * 3 + 1) % 256).collect();
    let want = greedy_generate(&w, &prompt, 4);
    for id in 0..3 {
        let resp = c.generate(Request::greedy(id, prompt.clone(), 4));
        assert_eq!(resp.tokens, want, "request {id}");
    }
    let m = c.metrics();
    assert_eq!(m.shard_workers.load(Ordering::Relaxed), 2);
    assert_eq!(m.shard_mode.load(Ordering::Relaxed), 2, "dp");
    assert!(
        m.shard_router_prefix_hits.load(Ordering::Relaxed) >= 2,
        "resubmitted prompts must route by prefix affinity"
    );
    assert!(
        m.kv_prefix_tokens_saved.load(Ordering::Relaxed) > 0,
        "affinity routing should turn into actual prefix-cache reuse"
    );
    c.shutdown();
}
