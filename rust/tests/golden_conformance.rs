//! Golden decode conformance: a tiny seeded model decoded across
//! {f32, int8} × {vanilla, surgeried} × {plain, speculative, chunked}
//! engines.
//!
//! Two layers of protection:
//!
//! 1. **Structural invariants, always checked** — within every
//!    (dtype, variant) configuration, the speculative greedy stream AND
//!    the chunked-prefill stream (tiny token budget, multi-chunk prompts)
//!    must be token-identical to the plain one (the tentpole guarantees,
//!    enforced without any golden file).
//! 2. **Committed golden traces** — `tests/golden/decode_traces.json`
//!    pins every configuration's token streams. A later change that shifts
//!    any stream (a kernel reorder, a quantizer tweak, an accidental
//!    nondeterminism) fails this test with a diff-able message. When the
//!    file does not exist yet — or `SKIPLESS_REGEN_GOLDEN=1` — the test
//!    writes it and passes; commit the generated file to pin the traces.

use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{CpuEngine, Request, Scheduler, SchedulerCfg};
use skipless::metrics::Metrics;
use skipless::model::{quantize, ModelWeights};
use skipless::surgery::{transform, Options};
use skipless::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

const SEED: u64 = 2027;
const MAX_NEW: usize = 10;

fn prompts() -> Vec<Vec<u32>> {
    vec![vec![3, 1, 4, 1, 5], vec![27, 18, 28], vec![100, 200, 1, 2, 3, 4]]
}

/// (name, weights) for every dtype × variant cell.
fn configurations() -> Vec<(String, ModelWeights)> {
    let cfg = ModelConfig::tiny_gqa();
    let vanilla = ModelWeights::init_vanilla(&cfg, SEED);
    let merged = transform(&vanilla, Variant::MergedQP, Options::default()).unwrap();
    vec![
        ("f32/vanilla".into(), vanilla.clone()),
        ("f32/merged_qp".into(), merged.clone()),
        ("int8/vanilla".into(), quantize(&vanilla)),
        ("int8/merged_qp".into(), quantize(&merged)),
    ]
}

/// Decode every prompt greedily through a scheduler — plain, speculative,
/// or with chunked prefill forced into multiple tiny chunks.
fn traces(w: &ModelWeights, spec_k: usize, chunked: bool) -> Vec<Vec<u32>> {
    let engine = CpuEngine::new(w.clone(), 4, 16 << 20);
    let cfg = if chunked {
        // budget smaller than the longest prompt and chunks that straddle
        // the 4-token block boundary: every admission genuinely chunks
        SchedulerCfg {
            token_budget_per_step: 5,
            chunk_tokens: 3,
            spec_k,
            ..Default::default()
        }
    } else {
        SchedulerCfg {
            spec_k,
            ..Default::default()
        }
    };
    let mut s = if spec_k > 0 {
        // self-speculation: the draft is the int8 form of the same weights
        // (idempotent for already-int8 targets)
        let draft = CpuEngine::new(quantize(w), 4, 16 << 20);
        Scheduler::with_draft(engine, Box::new(draft), cfg, Arc::new(Metrics::new()))
    } else {
        Scheduler::new(engine, cfg, Arc::new(Metrics::new()))
    };
    for (i, p) in prompts().into_iter().enumerate() {
        s.submit(Request::greedy(i as u64, p, MAX_NEW));
    }
    let mut done = s.run_to_completion();
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), prompts().len());
    done.into_iter().map(|r| r.tokens).collect()
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/decode_traces.json")
}

fn render(all: &[(String, Vec<Vec<u32>>, Vec<Vec<u32>>, Vec<Vec<u32>>)]) -> String {
    let arr = |t: &[Vec<u32>]| {
        let rows: Vec<String> = t
            .iter()
            .map(|r| {
                let xs: Vec<String> = r.iter().map(|t| t.to_string()).collect();
                format!("[{}]", xs.join(", "))
            })
            .collect();
        format!("[{}]", rows.join(", "))
    };
    let mut out = String::from("{\n");
    out.push_str("  \"model\": \"tiny-gqa\",\n");
    out.push_str(&format!("  \"seed\": {SEED},\n"));
    out.push_str(&format!("  \"max_new_tokens\": {MAX_NEW},\n"));
    out.push_str(&format!("  \"prompts\": {},\n", arr(&prompts())));
    out.push_str("  \"traces\": {\n");
    let cells: Vec<String> = all
        .iter()
        .flat_map(|(name, plain, spec, chunked)| {
            [
                format!("    \"{name}/plain\": {}", arr(plain)),
                format!("    \"{name}/speculative\": {}", arr(spec)),
                format!("    \"{name}/chunked\": {}", arr(chunked)),
            ]
        })
        .collect();
    out.push_str(&cells.join(",\n"));
    out.push_str("\n  }\n}\n");
    out
}

fn parse_traces(j: &Json, key: &str) -> Vec<Vec<u32>> {
    j.get("traces")
        .and_then(|t| t.get(key))
        .and_then(|a| a.as_arr())
        .unwrap_or_else(|| panic!("golden file has no trace for '{key}'"))
        .iter()
        .map(|row| {
            row.as_arr()
                .expect("trace row is an array")
                .iter()
                .map(|t| t.as_u64().expect("token id") as u32)
                .collect()
        })
        .collect()
}

#[test]
fn golden_decode_conformance() {
    // run every configuration all three ways
    let all: Vec<(String, Vec<Vec<u32>>, Vec<Vec<u32>>, Vec<Vec<u32>>)> = configurations()
        .into_iter()
        .map(|(name, w)| {
            let plain = traces(&w, 0, false);
            let spec = traces(&w, 4, false);
            let chunked = traces(&w, 0, true);
            (name, plain, spec, chunked)
        })
        .collect();

    // invariant 1 (no golden file needed): chunked ≡ monolithic ≡ spec,
    // per configuration
    for (name, plain, spec, chunked) in &all {
        assert_eq!(
            plain, spec,
            "{name}: speculative greedy decode diverged from plain decode"
        );
        assert_eq!(
            plain, chunked,
            "{name}: chunked prefill diverged from monolithic decode"
        );
    }
    // NB: no token-identity is asserted ACROSS variants or dtypes —
    // surgery preserves the function up to f32 roundoff (~1e-2 on logits)
    // and int8 shifts logits further, so their argmax streams may
    // legitimately differ. Each cell's stream is pinned by the golden file
    // below instead, which is what catches numeric drift over time.

    // golden diff (or bootstrap)
    let path = golden_path();
    let regen = std::env::var("SKIPLESS_REGEN_GOLDEN").is_ok();
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, render(&all)).unwrap();
        eprintln!(
            "golden_conformance: wrote {} — commit it to pin the traces",
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("bad golden file: {e}"));
    assert_eq!(
        j.get("seed").and_then(|s| s.as_u64()),
        Some(SEED),
        "golden file was generated for a different seed — regenerate with \
         SKIPLESS_REGEN_GOLDEN=1"
    );
    for (name, plain, spec, chunked) in &all {
        let want_plain = parse_traces(&j, &format!("{name}/plain"));
        let want_spec = parse_traces(&j, &format!("{name}/speculative"));
        let want_chunked = parse_traces(&j, &format!("{name}/chunked"));
        assert_eq!(
            plain, &want_plain,
            "{name}/plain drifted from the committed golden trace"
        );
        assert_eq!(
            spec, &want_spec,
            "{name}/speculative drifted from the committed golden trace"
        );
        assert_eq!(
            chunked, &want_chunked,
            "{name}/chunked drifted from the committed golden trace"
        );
    }
}
