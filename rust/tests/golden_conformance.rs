//! Golden decode conformance: a tiny seeded model decoded across
//! {f32, int8} × {vanilla, surgeried} × {plain, speculative, chunked}
//! engines, for greedy, stochastic (fixed per-request seeds), and
//! JSON-constrained request families.
//!
//! Two layers of protection:
//!
//! 1. **Structural invariants, always checked** — within every
//!    (dtype, variant) configuration and every request family, the
//!    speculative stream AND the chunked-prefill stream (tiny token
//!    budget, multi-chunk prompts) must be token-identical to the plain
//!    one. For the greedy family that is the original spec ≡ plain
//!    guarantee; for the stochastic families it is the RNG-stream
//!    discipline invariant (**stochastic spec ≡ plain stochastic for a
//!    fixed seed**), and every constrained stream must parse as JSON —
//!    all enforced without any golden file.
//! 2. **Committed golden traces** — `tests/golden/decode_traces.json`
//!    pins every configuration's token streams. A later change that shifts
//!    any stream (a kernel reorder, a quantizer tweak, an accidental
//!    nondeterminism) fails this test with a diff-able message. When the
//!    file does not exist yet — or `SKIPLESS_REGEN_GOLDEN=1` — the test
//!    writes it and passes; commit the generated file to pin the traces.

use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{CpuEngine, Request, Scheduler, SchedulerCfg};
use skipless::metrics::Metrics;
use skipless::model::{quantize, ModelWeights};
use skipless::sampler::grammar::Constraint;
use skipless::sampler::SamplerCfg;
use skipless::surgery::{transform, Options};
use skipless::util::json::Json;
use std::path::PathBuf;
use std::sync::Arc;

const SEED: u64 = 2027;
const MAX_NEW: usize = 10;

fn prompts() -> Vec<Vec<u32>> {
    vec![vec![3, 1, 4, 1, 5], vec![27, 18, 28], vec![100, 200, 1, 2, 3, 4]]
}

/// (name, weights) for every dtype × variant cell.
fn configurations() -> Vec<(String, ModelWeights)> {
    let cfg = ModelConfig::tiny_gqa();
    let vanilla = ModelWeights::init_vanilla(&cfg, SEED);
    let merged = transform(&vanilla, Variant::MergedQP, Options::default()).unwrap();
    vec![
        ("f32/vanilla".into(), vanilla.clone()),
        ("f32/merged_qp".into(), merged.clone()),
        ("int8/vanilla".into(), quantize(&vanilla)),
        ("int8/merged_qp".into(), quantize(&merged)),
    ]
}

/// A mixed-config stochastic request with a fixed per-request seed (the
/// seed is what lets spec and plain runs be compared stream-for-stream).
fn stochastic_req(id: u64, prompt: Vec<u32>) -> Request {
    let mut r = Request::greedy(id, prompt, MAX_NEW);
    r.seed = 900 + id;
    r.sampler = match id % 3 {
        0 => SamplerCfg {
            temperature: 0.8,
            ..Default::default()
        },
        1 => SamplerCfg {
            temperature: 0.7,
            top_k: 16,
            top_p: 0.9,
        },
        _ => SamplerCfg {
            temperature: 1.0,
            ..Default::default()
        },
    };
    r
}

/// A `"constrain":"json"` request (greedy when `temperature == 0.0`); a
/// roomy `max_new_tokens` lets the grammar close documents of its own
/// choosing rather than being budget-forced to `{}` immediately.
fn constrained_req(id: u64, prompt: Vec<u32>, temperature: f32) -> Request {
    let mut r = Request::greedy(id, prompt, 40);
    r.constrain = Some(Constraint::Json);
    r.seed = 7000 + id;
    if temperature > 0.0 {
        r.sampler = SamplerCfg {
            temperature,
            ..Default::default()
        };
    }
    r
}

/// Decode every prompt through a scheduler — plain, speculative, or with
/// chunked prefill forced into multiple tiny chunks — with per-request
/// construction delegated to `mk` (greedy, stochastic, constrained, ...).
fn traces_with(
    w: &ModelWeights,
    spec_k: usize,
    chunked: bool,
    mk: &dyn Fn(u64, Vec<u32>) -> Request,
) -> Vec<Vec<u32>> {
    let engine = CpuEngine::new(w.clone(), 4, 16 << 20);
    let cfg = if chunked {
        // budget smaller than the longest prompt and chunks that straddle
        // the 4-token block boundary: every admission genuinely chunks
        SchedulerCfg {
            token_budget_per_step: 5,
            chunk_tokens: 3,
            spec_k,
            ..Default::default()
        }
    } else {
        SchedulerCfg {
            spec_k,
            ..Default::default()
        }
    };
    let mut s = if spec_k > 0 {
        // self-speculation: the draft is the int8 form of the same weights
        // (idempotent for already-int8 targets)
        let draft = CpuEngine::new(quantize(w), 4, 16 << 20);
        Scheduler::with_draft(engine, Box::new(draft), cfg, Arc::new(Metrics::new()))
    } else {
        Scheduler::new(engine, cfg, Arc::new(Metrics::new()))
    };
    for (i, p) in prompts().into_iter().enumerate() {
        s.submit(mk(i as u64, p));
    }
    let mut done = s.run_to_completion();
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), prompts().len());
    done.into_iter().map(|r| r.tokens).collect()
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/decode_traces.json")
}

fn arr(t: &[Vec<u32>]) -> String {
    let rows: Vec<String> = t
        .iter()
        .map(|r| {
            let xs: Vec<String> = r.iter().map(|t| t.to_string()).collect();
            format!("[{}]", xs.join(", "))
        })
        .collect();
    format!("[{}]", rows.join(", "))
}

fn render(all: &[(String, Vec<(&'static str, Vec<Vec<u32>>)>)]) -> String {
    let mut out = String::from("{\n");
    out.push_str("  \"model\": \"tiny-gqa\",\n");
    out.push_str(&format!("  \"seed\": {SEED},\n"));
    out.push_str(&format!("  \"max_new_tokens\": {MAX_NEW},\n"));
    out.push_str(&format!("  \"prompts\": {},\n", arr(&prompts())));
    out.push_str("  \"traces\": {\n");
    let cells: Vec<String> = all
        .iter()
        .flat_map(|(name, fams)| {
            fams.iter()
                .map(|(key, t)| format!("    \"{name}/{key}\": {}", arr(t)))
        })
        .collect();
    out.push_str(&cells.join(",\n"));
    out.push_str("\n  }\n}\n");
    out
}

fn parse_traces(j: &Json, key: &str) -> Vec<Vec<u32>> {
    j.get("traces")
        .and_then(|t| t.get(key))
        .and_then(|a| a.as_arr())
        .unwrap_or_else(|| panic!("golden file has no trace for '{key}'"))
        .iter()
        .map(|row| {
            row.as_arr()
                .expect("trace row is an array")
                .iter()
                .map(|t| t.as_u64().expect("token id") as u32)
                .collect()
        })
        .collect()
}

#[test]
fn golden_decode_conformance() {
    let greedy: &dyn Fn(u64, Vec<u32>) -> Request = &|id, p| Request::greedy(id, p, MAX_NEW);
    let stochastic: &dyn Fn(u64, Vec<u32>) -> Request = &stochastic_req;
    let constrained: &dyn Fn(u64, Vec<u32>) -> Request = &|id, p| constrained_req(id, p, 0.0);
    let constrained_stochastic: &dyn Fn(u64, Vec<u32>) -> Request =
        &|id, p| constrained_req(id, p, 0.9);

    // run every configuration × family, each all three ways, asserting the
    // mode-invariance structurally (invariant 1; no golden file needed)
    let mut all: Vec<(String, Vec<(&'static str, Vec<Vec<u32>>)>)> = Vec::new();
    for (name, w) in configurations() {
        let mut cells: Vec<(&'static str, Vec<Vec<u32>>)> = Vec::new();
        // greedy family: all three modes are pinned individually (the
        // original golden layout)
        let plain = traces_with(&w, 0, false, greedy);
        let spec = traces_with(&w, 4, false, greedy);
        let chunked = traces_with(&w, 0, true, greedy);
        assert_eq!(
            &plain, &spec,
            "{name}: speculative greedy decode diverged from plain decode"
        );
        assert_eq!(
            &plain, &chunked,
            "{name}: chunked prefill diverged from monolithic decode"
        );
        cells.push(("plain", plain));
        cells.push(("speculative", spec));
        cells.push(("chunked", chunked));
        // stochastic / constrained families: spec ≡ plain ≡ chunked for
        // fixed seeds (RNG stream discipline), constrained streams parse;
        // the plain trace is the one pinned in the golden file
        for (fam, mk, must_parse) in [
            ("stochastic", stochastic, false),
            ("constrained", constrained, true),
            ("constrained_stochastic", constrained_stochastic, true),
        ] {
            let plain = traces_with(&w, 0, false, mk);
            let spec = traces_with(&w, 4, false, mk);
            let chunked = traces_with(&w, 0, true, mk);
            assert_eq!(
                &plain, &spec,
                "{name}/{fam}: speculative decode diverged from plain decode \
                 (RNG stream discipline broken)"
            );
            assert_eq!(
                &plain, &chunked,
                "{name}/{fam}: chunked prefill diverged from monolithic decode"
            );
            if must_parse {
                for t in &plain {
                    let bytes: Vec<u8> = t
                        .iter()
                        .map(|&x| u8::try_from(x).expect("constrained tokens are byte-vocab"))
                        .collect();
                    let text = String::from_utf8_lossy(&bytes).into_owned();
                    Json::parse(&text).unwrap_or_else(|e| {
                        panic!("{name}/{fam}: constrained output {text:?} must parse: {e}")
                    });
                }
            }
            cells.push((fam, plain));
        }
        all.push((name, cells));
    }
    // NB: no token-identity is asserted ACROSS variants or dtypes —
    // surgery preserves the function up to f32 roundoff (~1e-2 on logits)
    // and int8 shifts logits further, so their argmax streams may
    // legitimately differ. Each cell's stream is pinned by the golden file
    // below instead, which is what catches numeric drift over time.

    // golden diff (or bootstrap)
    let path = golden_path();
    let regen = std::env::var("SKIPLESS_REGEN_GOLDEN").is_ok();
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, render(&all)).unwrap();
        eprintln!(
            "golden_conformance: wrote {} — commit it to pin the traces",
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("bad golden file: {e}"));
    assert_eq!(
        j.get("seed").and_then(|s| s.as_u64()),
        Some(SEED),
        "golden file was generated for a different seed — regenerate with \
         SKIPLESS_REGEN_GOLDEN=1"
    );
    for (name, fams) in &all {
        for (key, got) in fams {
            let want = parse_traces(&j, &format!("{name}/{key}"));
            assert_eq!(
                got, &want,
                "{name}/{key} drifted from the committed golden trace"
            );
        }
    }
}
