//! Cross-layer integration: the AOT-compiled JAX/Pallas artifacts (L1+L2)
//! executed through the PJRT runtime must agree with the pure-Rust CPU
//! engine (L3's reference) on the SAME weights — prefill, decode, batched
//! decode, vanilla and merged — and compose with the coordinator + server.
//!
//! Requires `make artifacts` (skips with a message otherwise — CI runs
//! artifacts first).

use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{
    Coordinator, CpuEngine, DecodeInput, Engine, Request, SchedulerCfg,
};
use skipless::model::ModelWeights;
use skipless::runtime::PjrtEngine;
use skipless::surgery::{transform, Options};
use std::path::{Path, PathBuf};

fn artifact_dir(variant: &str) -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/tiny-gqa")
        .join(variant);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: {dir:?} missing — run `make artifacts` first");
        None
    }
}

fn weights(variant: Variant) -> ModelWeights {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 4242);
    match variant {
        Variant::Vanilla => w,
        v => transform(&w, v, Options::default()).unwrap(),
    }
}

fn max_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn pjrt_matches_cpu_engine_vanilla_and_merged() {
    for (vname, variant) in [("vanilla", Variant::Vanilla), ("merged_qp", Variant::MergedQP)] {
        let Some(dir) = artifact_dir(vname) else { return };
        let w = weights(variant);
        let mut pjrt = PjrtEngine::boot(&dir, &w, 8).expect("boot");
        let mut cpu = CpuEngine::new(w, 8, 16 << 20);

        // prefill agreement (prompt shorter than the bucket → padding path)
        let prompt = [5u32, 17, 3, 42, 8];
        let (pid, pl) = pjrt.prefill(&prompt).unwrap();
        let (cid, cl) = cpu.prefill(&prompt).unwrap();
        let err = max_err(&pl, &cl);
        assert!(err < 2e-3, "{vname}: prefill logits err {err}");

        // several decode steps
        let mut tok = 7u32;
        for step in 0..6 {
            let pg = pjrt
                .decode_batch(&[DecodeInput { seq: pid, token: tok }])
                .unwrap();
            let cg = cpu
                .decode_batch(&[DecodeInput { seq: cid, token: tok }])
                .unwrap();
            let err = max_err(&pg[0], &cg[0]);
            assert!(err < 2e-3, "{vname}: decode step {step} err {err}");
            tok = (tok * 31 + 17) % 250;
        }
        pjrt.release(pid);
    }
}

#[test]
fn pjrt_batched_decode_matches_singles() {
    let Some(dir) = artifact_dir("vanilla") else { return };
    let w = weights(Variant::Vanilla);
    let mut eng = PjrtEngine::boot(&dir, &w, 8).unwrap();
    let prompts: [&[u32]; 3] = [&[1, 2, 3], &[9, 8], &[5, 5, 5, 5]];
    let ids: Vec<_> = prompts.iter().map(|p| eng.prefill(p).unwrap().0).collect();
    // batch of 3 → runs in the b4 bucket with one padded row
    let batch: Vec<DecodeInput> = ids
        .iter()
        .zip([11u32, 22, 33])
        .map(|(&seq, token)| DecodeInput { seq, token })
        .collect();
    let got = eng.decode_batch(&batch).unwrap();
    // fresh engine, one-at-a-time (b1 bucket)
    let mut eng2 = PjrtEngine::boot(&dir, &w, 8).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        let (id, _) = eng2.prefill(p).unwrap();
        let want = eng2
            .decode_batch(&[DecodeInput { seq: id, token: [11u32, 22, 33][i] }])
            .unwrap();
        let err = max_err(&got[i], &want[0]);
        assert!(err < 2e-3, "row {i} err {err}");
    }
}

#[test]
fn pjrt_vanilla_and_merged_agree_end_to_end() {
    // The paper's claim at the whole-system level: same tokens out.
    let (Some(dv), Some(dm)) = (artifact_dir("vanilla"), artifact_dir("merged_qp")) else {
        return;
    };
    let coord_v = Coordinator::spawn_with(
        {
            let w = weights(Variant::Vanilla);
            move || PjrtEngine::boot(&dv, &w, 8).unwrap()
        },
        SchedulerCfg::default(),
    );
    let coord_m = Coordinator::spawn_with(
        {
            let w = weights(Variant::MergedQP);
            move || PjrtEngine::boot(&dm, &w, 8).unwrap()
        },
        SchedulerCfg::default(),
    );
    for (i, prompt) in [vec![1u32, 2, 3], vec![100, 50], vec![7, 7, 7, 7, 7]]
        .into_iter()
        .enumerate()
    {
        let rv = coord_v.generate(Request::greedy(i as u64, prompt.clone(), 8));
        let rm = coord_m.generate(Request::greedy(i as u64, prompt, 8));
        assert_eq!(rv.tokens, rm.tokens, "prompt {i}: merged diverged");
        assert_eq!(rv.tokens.len(), 8);
    }
    coord_v.shutdown();
    coord_m.shutdown();
}

#[test]
fn pjrt_capacity_and_errors() {
    let Some(dir) = artifact_dir("vanilla") else { return };
    let w = weights(Variant::Vanilla);
    let mut eng = PjrtEngine::boot(&dir, &w, 2).unwrap();
    assert!(eng.can_admit(5));
    assert!(!eng.can_admit(100), "prompt larger than any bucket");
    let (a, _) = eng.prefill(&[1, 2]).unwrap();
    let (_b, _) = eng.prefill(&[3, 4]).unwrap();
    assert!(!eng.can_admit(2), "max_seqs reached");
    assert!(eng.prefill(&[5]).is_err());
    eng.release(a);
    assert!(eng.can_admit(2));
    // wrong-variant weights rejected at boot
    let wm = weights(Variant::MergedQP);
    assert!(PjrtEngine::boot(&dir, &wm, 2).is_err());
}
