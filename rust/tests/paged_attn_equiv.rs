//! Paged-vs-gathered bit-identity property suite.
//!
//! The zero-copy paged attention kernel must produce BYTE-equal output to
//! the old gather-then-attend reference (`paged_attn::attend_gathered`,
//! the pre-change decode kernel kept as the oracle) over:
//!
//!   {f32, u8 KV} × {MHA, GQA, MQA} × block_tokens ∈ {1, 3, 16}
//!   × sequences spanning partial / CoW-forked / swap-resumed blocks,
//!
//! plus in-register tail segments (the current decode row, and a verify
//! step's split roundtripped-tail + raw-row shape). Seeded pseudo-random
//! contents throughout — failures reproduce.

use skipless::config::ModelConfig;
use skipless::kvcache::{BlockView, CacheOpts, KvCache, SeqId};
use skipless::model::attention::HeadLayout;
use skipless::model::paged_attn::{attend_batch, attend_gathered, attend_paged, AttnItem, KvSegment};
use skipless::tensor::Mat;
use skipless::util::rng::Xoshiro256;

fn layout_of(cfg: &ModelConfig) -> HeadLayout {
    HeadLayout {
        n_heads: cfg.n_heads,
        n_kv_heads: cfg.n_kv_heads,
        head_dim: cfg.head_dim(),
    }
}

fn fill_random(c: &mut KvCache, cfg: &ModelConfig, id: SeqId, n: usize, rng: &mut Xoshiro256) {
    let e = cfg.e();
    for _ in 0..n {
        for layer in 0..cfg.n_layers {
            let k = Mat::randn(1, e, 0.8, rng);
            let v = Mat::randn(1, e, 0.8, rng);
            c.append(id, layer, k.row(0), v.row(0)).unwrap();
        }
        c.advance(id).unwrap();
    }
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Assert paged output over `id`'s views + `tails` is byte-equal to the
/// gather + reference path on `layer`, for a fresh random query.
fn assert_bit_identical(
    c: &mut KvCache,
    layout: HeadLayout,
    id: SeqId,
    layer: usize,
    tails: &[KvSegment<'_>; 2],
    rng: &mut Xoshiro256,
    tag: &str,
) {
    let q = Mat::randn(1, layout.d(), 0.5, rng);
    let n_tail: usize = tails.iter().map(|s| s.n).sum();
    // reference: copy the history out, splice the tails on, attend
    let (mut kg, mut vg) = (Vec::new(), Vec::new());
    let t_cache = c.gather(id, layer, &mut kg, &mut vg).unwrap();
    for seg in tails {
        kg.extend_from_slice(seg.k);
        vg.extend_from_slice(seg.v);
    }
    let t = t_cache + n_tail;
    let mut want = vec![0.0f32; layout.d()];
    attend_gathered(layout, q.row(0), &kg, &vg, t, &mut want);
    // paged: same query, zero-copy views
    let views: Vec<BlockView> = c.seq_block_views(id, layer).unwrap().collect();
    let mut got = vec![0.0f32; layout.d()];
    let mut scores = Vec::new();
    attend_paged(layout, q.row(0), &views, tails, t, &mut scores, &mut got);
    assert_eq!(bits(&got), bits(&want), "{tag}: paged != gathered");
}

/// The headline grid: layouts × precisions × block sizes × history lengths
/// (full and partial tail blocks) × tail shapes.
#[test]
fn paged_matches_gathered_across_layouts_precisions_block_sizes() {
    for name in ["tiny-mha", "tiny-gqa", "tiny-mqa"] {
        for quantized in [false, true] {
            for bt in [1usize, 3, 16] {
                let cfg = ModelConfig::preset(name).unwrap();
                let layout = layout_of(&cfg);
                let e = cfg.e();
                let mut c = KvCache::with_opts(
                    &cfg,
                    bt,
                    512 * 1024,
                    CacheOpts { quantized, ..Default::default() },
                );
                let mut rng = Xoshiro256::seed_from_u64(40 + bt as u64);
                for t_cache in [1usize, 3, 8, 19, 32] {
                    let id = c.alloc_seq(t_cache).unwrap();
                    fill_random(&mut c, &cfg, id, t_cache, &mut rng);
                    let tail = Mat::randn(4, e, 0.5, &mut rng);
                    for layer in 0..cfg.n_layers {
                        let tag =
                            format!("{name} kv8={quantized} bt={bt} t={t_cache} layer={layer}");
                        // bare history (no tail)
                        assert_bit_identical(
                            &mut c, layout, id, layer,
                            &[KvSegment::empty(), KvSegment::empty()],
                            &mut rng, &tag,
                        );
                        // decode shape: one raw in-register row
                        assert_bit_identical(
                            &mut c, layout, id, layer,
                            &[
                                KvSegment::rows(tail.row(0), tail.row(1), e),
                                KvSegment::empty(),
                            ],
                            &mut rng, &tag,
                        );
                        // verify shape: roundtripped tail + raw current row
                        assert_bit_identical(
                            &mut c, layout, id, layer,
                            &[
                                KvSegment::rows(tail.row(0), tail.row(1), e),
                                KvSegment::rows(tail.row(2), tail.row(3), e),
                            ],
                            &mut rng, &tag,
                        );
                    }
                    c.free_seq(id).unwrap();
                }
            }
        }
    }
}

/// CoW-forked sequences: after a fork diverges inside a shared tail block,
/// both the fork and the original must stay bit-identical to their own
/// gathered reference (views follow each sequence's own block table).
#[test]
fn paged_matches_gathered_across_cow_forks() {
    for quantized in [false, true] {
        let cfg = ModelConfig::tiny_gqa();
        let layout = layout_of(&cfg);
        let mut c = KvCache::with_opts(
            &cfg,
            4,
            512 * 1024,
            CacheOpts { quantized, ..Default::default() },
        );
        let mut rng = Xoshiro256::seed_from_u64(50);
        let id = c.alloc_seq(6).unwrap();
        fill_random(&mut c, &cfg, id, 6, &mut rng);
        let f = c.fork_seq(id).unwrap();
        fill_random(&mut c, &cfg, f, 1, &mut rng); // CoW in shared tail block
        fill_random(&mut c, &cfg, id, 2, &mut rng); // original diverges too
        for seq in [id, f] {
            for layer in 0..cfg.n_layers {
                assert_bit_identical(
                    &mut c, layout, seq, layer,
                    &[KvSegment::empty(), KvSegment::empty()],
                    &mut rng,
                    &format!("kv8={quantized} cow seq={seq:?} layer={layer}"),
                );
            }
        }
    }
}

/// Swap-resumed sequences: a swap-out/swap-in cycle (blocks restored into
/// different physical slots, prefix blocks possibly re-borrowed) must not
/// perturb the paged read path.
#[test]
fn paged_matches_gathered_after_swap_resume() {
    for quantized in [false, true] {
        let cfg = ModelConfig::tiny_gqa();
        let layout = layout_of(&cfg);
        let mut c = KvCache::with_opts(
            &cfg,
            4,
            512 * 1024,
            CacheOpts { quantized, ..Default::default() },
        );
        let mut rng = Xoshiro256::seed_from_u64(60);
        let id = c.alloc_seq(7).unwrap();
        fill_random(&mut c, &cfg, id, 7, &mut rng);
        c.swap_out(id).unwrap();
        // churn the pool so restored blocks land elsewhere
        let other = c.alloc_seq(8).unwrap();
        fill_random(&mut c, &cfg, other, 8, &mut rng);
        c.free_seq(other).unwrap();
        c.swap_in(id).unwrap();
        for layer in 0..cfg.n_layers {
            assert_bit_identical(
                &mut c, layout, id, layer,
                &[KvSegment::empty(), KvSegment::empty()],
                &mut rng,
                &format!("kv8={quantized} swap layer={layer}"),
            );
        }
    }
}

/// The threaded (sequence × head) batch driver must agree bit-for-bit with
/// per-item serial evaluation over a mixed-length batch.
#[test]
fn batch_grid_bit_identical_to_serial() {
    let cfg = ModelConfig::tiny_gqa();
    let layout = layout_of(&cfg);
    let e = cfg.e();
    let mut c = KvCache::new(&cfg, 4, 512 * 1024);
    let mut rng = Xoshiro256::seed_from_u64(70);
    let lens = [33usize, 64, 47, 80, 5, 71];
    let ids: Vec<SeqId> = lens
        .iter()
        .map(|&n| {
            let id = c.alloc_seq(n).unwrap();
            fill_random(&mut c, &cfg, id, n, &mut rng);
            id
        })
        .collect();
    let q = Mat::randn(lens.len(), layout.d(), 0.5, &mut rng);
    let cur = Mat::randn(lens.len(), 2 * e, 0.5, &mut rng);
    let mut views: Vec<BlockView> = Vec::new();
    let mut ranges = Vec::new();
    for &id in &ids {
        let start = views.len();
        views.extend(c.seq_block_views(id, 0).unwrap());
        ranges.push((start, views.len()));
    }
    let items: Vec<AttnItem> = ids
        .iter()
        .enumerate()
        .map(|(r, _)| AttnItem {
            q_rot: q.row(r),
            views: &views[ranges[r].0..ranges[r].1],
            cache_len: lens[r],
            tails: [
                KvSegment::rows(&cur.row(r)[..e], &cur.row(r)[e..], e),
                KvSegment::empty(),
            ],
            t: lens[r] + 1,
            out_row: r,
        })
        .collect();
    let mut serial = Mat::zeros(lens.len(), layout.d());
    let mut scores = Vec::new();
    for it in &items {
        attend_paged(
            layout, it.q_rot, it.views, &it.tails, it.t, &mut scores,
            serial.row_mut(it.out_row),
        );
    }
    let mut parallel = Mat::zeros(lens.len(), layout.d());
    attend_batch(layout, &items, &mut parallel);
    assert_eq!(bits(parallel.as_slice()), bits(serial.as_slice()));
}
