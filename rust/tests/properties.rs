//! Randomized property tests (proptest-style, driven by the in-tree
//! Xoshiro PRNG since the offline image ships no proptest crate).
//!
//! Each property runs many randomized cases with the failing seed printed,
//! so a failure is reproducible by fixing `CASE_SEED`.
//!
//! Invariants covered:
//! * surgery: equivalence holds for EVERY seed/config/variant (not just
//!   the unit tests' fixed seeds); weight deltas always match `params`.
//! * quantization: round-trip error bounded per row, `qgemm` tracks the
//!   f32 GEMM on random shapes, INT8 logits track f32 across every tiny
//!   preset × surgery variant, and batched INT8 decode stays bit-equal to
//!   sequential decode.
//! * scheduler/coordinator: conservation (every submitted request gets
//!   exactly one response), ordering-independence of results, KV-cache
//!   leak-freedom under random admission/finish/preemption churn.
//! * kvcache: alloc/free conservation, no cross-sequence aliasing.
//! * tokenizer: encode∘decode = identity for arbitrary byte strings.

use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{CpuEngine, DecodeInput, Engine, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::KvCache;
use skipless::linalg::{matmul, qmatmul};
use skipless::metrics::Metrics;
use skipless::model::{prefill, quantize, ModelWeights};
use skipless::sampler::SamplerCfg;
use skipless::surgery::{transform, Options};
use skipless::tensor::{Mat, QMat};
use skipless::tokenizer::Bpe;
use skipless::util::rng::Xoshiro256;
use std::sync::Arc;

const CASE_SEED: u64 = 0xC0FFEE;

/// Property: Table-1 surgery preserves logits for random seeds × configs ×
/// variants (20 random cases).
#[test]
fn prop_surgery_equivalence_random_cases() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED);
    let presets = ["tiny-mha", "tiny-gqa", "tiny-mqa", "tiny-parallel"];
    for case in 0..20 {
        let preset = presets[rng.next_below(presets.len() as u64) as usize];
        let cfg = ModelConfig::preset(preset).unwrap();
        let seed = rng.next_u64();
        let variants: Vec<Variant> = Variant::all()
            .into_iter()
            .filter(|&v| v != Variant::Vanilla && cfg.supports(v))
            .collect();
        let variant = variants[rng.next_below(variants.len() as u64) as usize];
        let w = ModelWeights::init_vanilla(&cfg, seed);
        let m = transform(&w, variant, Options { skip_audit: true, ..Default::default() })
            .unwrap_or_else(|e| panic!("case {case} ({preset},{variant:?},seed {seed}): {e}"));
        // random prompt
        let len = 1 + rng.next_below(10) as usize;
        let prompt: Vec<u32> = (0..len)
            .map(|_| rng.next_below(cfg.vocab_size as u64) as u32)
            .collect();
        let (l0, _) = prefill(&w, &prompt);
        let (l1, _) = prefill(&m, &prompt);
        let err = l1.rel_fro_err(&l0);
        assert!(
            err < 1e-3,
            "case {case}: {preset} {variant:?} seed {seed} prompt {prompt:?}: rel err {err}"
        );
        // weight-count delta always matches the analytic table
        use skipless::params::count_weights;
        if cfg.layout == skipless::config::BlockLayout::Serial {
            assert_eq!(m.stored_weights(), count_weights(&cfg, variant).total());
        }
    }
}

/// Property: per-row symmetric quantization round-trips every element of
/// every random matrix within half a quantization step (`scale/2`).
#[test]
fn prop_quant_roundtrip_bounded_per_row() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 10);
    for case in 0..30 {
        let rows = 1 + rng.next_below(40) as usize;
        let cols = 1 + rng.next_below(120) as usize;
        let std = 0.01 + rng.next_below(1000) as f32 / 100.0; // 0.01 .. 10
        let m = Mat::randn(rows, cols, std, &mut rng);
        let q = QMat::quantize_rows(&m);
        let back = q.dequantize();
        for r in 0..rows {
            // half a step, plus scale-relative slack for f32 rounding of
            // x·(1/scale) near the .5 boundary
            let bound = q.scale(r) * 0.5001 + 1e-6;
            for c in 0..cols {
                let err = (m.at(r, c) - back.at(r, c)).abs();
                assert!(
                    err <= bound,
                    "case {case} ({rows}x{cols}, std {std}): [{r},{c}] err {err} > {bound}"
                );
            }
        }
    }
}

/// Property: the INT8 GEMM tracks the f32 GEMM on random shapes and seeds
/// (per-channel weight scales + per-row activation scales keep the
/// relative Frobenius error at the ~1% quantization floor).
#[test]
fn prop_qgemm_matches_f32_gemm() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 11);
    for case in 0..20 {
        let m = 1 + rng.next_below(32) as usize;
        let k = 1 + rng.next_below(300) as usize;
        let n = 1 + rng.next_below(400) as usize;
        let x = Mat::randn(m, k, 1.0, &mut rng);
        let w = Mat::randn(k, n, 1.0, &mut rng);
        let got = qmatmul(&x, &QMat::from_weight(&w));
        let want = matmul(&x, &w);
        let err = got.rel_fro_err(&want);
        assert!(err < 0.03, "case {case} ({m},{k},{n}): rel err {err}");
    }
}

/// Property: INT8 logits track f32 logits within rel-Fro 5e-2 for EVERY
/// tiny preset × supported surgery variant (the ISSUE-2 acceptance bar),
/// on random prompts.
#[test]
fn prop_int8_logit_drift_all_presets_and_variants() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 12);
    for preset in ["tiny-mha", "tiny-gqa", "tiny-mqa", "tiny-parallel"] {
        let cfg = ModelConfig::preset(preset).unwrap();
        let w = ModelWeights::init_vanilla(&cfg, rng.next_u64());
        for variant in Variant::all() {
            if !cfg.supports(variant) {
                continue;
            }
            let merged = transform(&w, variant, Options { skip_audit: true, ..Default::default() })
                .unwrap();
            let q = quantize(&merged);
            let len = 1 + rng.next_below(8) as usize;
            let prompt: Vec<u32> = (0..len)
                .map(|_| rng.next_below(cfg.vocab_size as u64) as u32)
                .collect();
            let (l0, _) = prefill(&merged, &prompt);
            let (l1, _) = prefill(&q, &prompt);
            let err = l1.rel_fro_err(&l0);
            assert!(
                err < 5e-2,
                "{preset} {variant:?} prompt {prompt:?}: int8 rel err {err}"
            );
        }
    }
}

/// Property: batched INT8 decode equals sequential INT8 decode bit-exactly
/// (quantization is per-row, so batching cannot change any row's result).
#[test]
fn prop_int8_decode_batch_invariant() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 13);
    let cfg = ModelConfig::tiny_gqa();
    let q = quantize(&ModelWeights::init_vanilla(&cfg, rng.next_u64()));
    let mut eng_b = CpuEngine::new(q.clone(), 8, 8 << 20);
    let mut eng_s = CpuEngine::new(q, 8, 8 << 20);
    let prompts: Vec<Vec<u32>> = (0..5)
        .map(|i| (0..(2 + i)).map(|j| ((i * 37 + j * 11 + 1) % 250) as u32).collect())
        .collect();
    let ids_b: Vec<_> = prompts.iter().map(|p| eng_b.prefill(p).unwrap().0).collect();
    let ids_s: Vec<_> = prompts.iter().map(|p| eng_s.prefill(p).unwrap().0).collect();
    for step in 0..3 {
        let toks: Vec<u32> = (0..prompts.len())
            .map(|i| ((step * 41 + i * 17 + 2) % 250) as u32)
            .collect();
        let batch: Vec<DecodeInput> = ids_b
            .iter()
            .zip(&toks)
            .map(|(&seq, &token)| DecodeInput { seq, token })
            .collect();
        let got = eng_b.decode_batch(&batch).unwrap();
        for (i, (&seq, &token)) in ids_s.iter().zip(&toks).enumerate() {
            let solo = eng_s.decode_batch(&[DecodeInput { seq, token }]).unwrap();
            assert_eq!(got[i], solo[0], "step {step} seq {i}: batch changed int8 logits");
        }
    }
}

/// Property: every submitted request produces exactly one response with
/// ≤ max_new_tokens tokens, across random workloads and queue pressure.
#[test]
fn prop_scheduler_conservation() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 1);
    for case in 0..8 {
        let cfg = ModelConfig::tiny_mha();
        let w = ModelWeights::init_vanilla(&cfg, rng.next_u64());
        // randomly tight or roomy cache
        let budget = if rng.next_below(2) == 0 { 96 << 10 } else { 8 << 20 };
        let mut s = Scheduler::new(
            CpuEngine::new(w, 8, budget),
            SchedulerCfg {
                max_running: 1 + rng.next_below(6) as usize,
                token_budget_per_step: 4 + rng.next_below(60) as usize,
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        let n_reqs = 3 + rng.next_below(10) as usize;
        let mut expected: Vec<u64> = Vec::new();
        for i in 0..n_reqs {
            let plen = 1 + rng.next_below(6) as usize;
            let prompt: Vec<u32> = (0..plen).map(|_| rng.next_below(250) as u32).collect();
            let max_new = 1 + rng.next_below(6) as usize;
            let mut req = Request::greedy(i as u64, prompt, max_new);
            if rng.next_below(3) == 0 {
                req.sampler = SamplerCfg {
                    temperature: 0.8,
                    top_k: 10,
                    top_p: 0.95,
                };
                req.seed = rng.next_u64();
            }
            expected.push(req.id);
            s.submit(req);
        }
        let mut done = s.run_to_completion();
        done.sort_by_key(|r| r.id);
        let got: Vec<u64> = done.iter().map(|r| r.id).collect();
        assert_eq!(got, expected, "case {case}: lost or duplicated responses");
        for r in &done {
            assert!(
                r.tokens.len() <= 6,
                "case {case} req {}: {} tokens",
                r.id,
                r.tokens.len()
            );
        }
    }
}

/// Property: results are independent of submission interleaving — a batch
/// submitted all at once equals the same requests submitted one by one.
#[test]
fn prop_scheduler_order_independence() {
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 4711);
    let prompts: Vec<Vec<u32>> = (0..6).map(|i| vec![(i * 31 + 7) as u32 % 250, 3, 9]).collect();

    let run = |batched: bool| -> Vec<Vec<u32>> {
        let mut s = Scheduler::new(
            CpuEngine::new(w.clone(), 8, 8 << 20),
            SchedulerCfg::default(),
            Arc::new(Metrics::new()),
        );
        let mut out = vec![Vec::new(); prompts.len()];
        if batched {
            for (i, p) in prompts.iter().enumerate() {
                s.submit(Request::greedy(i as u64, p.clone(), 5));
            }
            for r in s.run_to_completion() {
                out[r.id as usize] = r.tokens;
            }
        } else {
            for (i, p) in prompts.iter().enumerate() {
                s.submit(Request::greedy(i as u64, p.clone(), 5));
                for r in s.run_to_completion() {
                    out[r.id as usize] = r.tokens;
                }
            }
        }
        out
    };
    assert_eq!(run(true), run(false), "batching changed results");
}

/// Property: the engine never leaks KV blocks — after any random workload
/// completes, the cache is back to fully free.
#[test]
fn prop_engine_no_cache_leak() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 2);
    for case in 0..6 {
        let cfg = ModelConfig::tiny_mqa();
        let w = ModelWeights::init_vanilla(&cfg, rng.next_u64());
        let mut s = Scheduler::new(
            CpuEngine::new(w, 4, 256 << 10),
            SchedulerCfg {
                max_running: 4,
                ..Default::default()
            },
            Arc::new(Metrics::new()),
        );
        for i in 0..8u64 {
            let plen = 1 + rng.next_below(5) as usize;
            let prompt: Vec<u32> = (0..plen).map(|_| rng.next_below(200) as u32).collect();
            s.submit(Request::greedy(i, prompt, 1 + rng.next_below(5) as usize));
        }
        let done = s.run_to_completion();
        assert_eq!(done.len(), 8, "case {case}");
        // all sequences released ⇒ engine will admit a max-size prompt again
        assert!(s.engine().can_admit(16), "case {case}: blocks leaked");
    }
}

/// Property: paged cache conservation + isolation under random alloc/free
/// churn with interleaved appends.
#[test]
fn prop_kvcache_conservation_and_isolation() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 3);
    let cfg = ModelConfig::tiny_gqa();
    let mut cache = KvCache::new(&cfg, 4, 512 << 10);
    let total = cache.free_blocks();
    let e = cfg.e();
    let mut live: Vec<(skipless::kvcache::SeqId, u64, usize)> = Vec::new(); // (id, tag, len)
    for _step in 0..300 {
        match rng.next_below(3) {
            0 if cache.can_admit(2) && live.len() < 12 => {
                let id = cache.alloc_seq(2).unwrap();
                live.push((id, rng.next_u64(), 0));
            }
            1 if !live.is_empty() => {
                let idx = rng.next_below(live.len() as u64) as usize;
                let (id, _, _) = live.remove(idx);
                cache.free_seq(id).unwrap();
            }
            _ if !live.is_empty() => {
                let idx = rng.next_below(live.len() as u64) as usize;
                let (id, tag, ref mut len) = live[idx];
                let val = (tag ^ *len as u64) as f32;
                let row = vec![val; e];
                let mut ok = true;
                for l in 0..cfg.n_layers {
                    if cache.append(id, l, &row, &row).is_err() {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    cache.advance(id).unwrap();
                    *len += 1;
                }
            }
            _ => {}
        }
    }
    // isolation: each live sequence sees exactly its own tagged values
    let (mut k, mut v) = (Vec::new(), Vec::new());
    for &(id, tag, len) in &live {
        let got = cache.gather(id, 0, &mut k, &mut v).unwrap();
        assert_eq!(got, len);
        for (pos, chunk) in k.chunks(e).enumerate() {
            let want = (tag ^ pos as u64) as f32;
            assert!(chunk.iter().all(|&x| x == want), "seq {id:?} pos {pos}");
        }
    }
    // conservation: free everything → all blocks return
    for (id, _, _) in live {
        cache.free_seq(id).unwrap();
    }
    assert_eq!(cache.free_blocks(), total);
}

/// Property: BPE encode/decode is the identity on arbitrary byte strings.
#[test]
fn prop_tokenizer_roundtrip_random_bytes() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 4);
    let bpe = Bpe::train(
        "the quick brown fox jumps over the lazy dog again and again and again",
        380,
    );
    for case in 0..200 {
        let len = rng.next_below(120) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let toks = bpe.encode(&text);
        assert_eq!(
            bpe.decode(&toks),
            text.as_bytes(),
            "case {case}: roundtrip failed"
        );
        for &t in &toks {
            assert!((t as usize) < bpe.vocab_size(), "case {case}: oov token");
        }
    }
}

/// Property: greedy generation through the scheduler equals direct model
/// generation for random prompts (the serving stack adds nothing).
#[test]
fn prop_serving_matches_model() {
    let mut rng = Xoshiro256::seed_from_u64(CASE_SEED + 5);
    let cfg = ModelConfig::tiny_gqa();
    let w = ModelWeights::init_vanilla(&cfg, 31337);
    for case in 0..10 {
        let plen = 1 + rng.next_below(8) as usize;
        let prompt: Vec<u32> = (0..plen).map(|_| rng.next_below(250) as u32).collect();
        let n = 1 + rng.next_below(8) as usize;
        let want = skipless::model::greedy_generate(&w, &prompt, n);
        let mut s = Scheduler::new(
            CpuEngine::new(w.clone(), 8, 8 << 20),
            SchedulerCfg::default(),
            Arc::new(Metrics::new()),
        );
        s.submit(Request::greedy(0, prompt.clone(), n));
        let done = s.run_to_completion();
        assert_eq!(done[0].tokens, want, "case {case}: prompt {prompt:?}");
    }
}
