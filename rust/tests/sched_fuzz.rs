//! Scheduler interleaving fuzz: seeded random schedules of submit / step /
//! cancel — admission, chunked prefill under tight token budgets, capacity
//! preemption, mid-prefill swap-out, and resume all arise from the
//! deliberately tiny KV pools — with speculative decoding on or off. The
//! request mix covers greedy, temperature/nucleus-sampled (per-request
//! seeds), EOS-cut, and `"constrain":"json"` grammar-masked requests.
//! Every surviving request's output must be byte-identical to a sequential
//! single-request oracle (a cancelled request may only ever deliver a
//! prefix of its oracle stream) — for stochastic requests that is exactly
//! the "stochastic spec ≡ plain stochastic for a fixed seed" RNG-stream
//! invariant — no request may ever be dropped or spuriously rejected, and
//! every non-cancelled constrained output must parse as JSON and finish
//! via grammar completion.
//!
//! `SKIPLESS_QUANTIZE=int8` (the CI matrix leg) runs the whole fuzz on
//! INT8 engines: the target, the oracle, and the draft are all quantized,
//! so streams are still compared within one numeric configuration.

use skipless::config::ModelConfig;
use skipless::coordinator::{CpuEngine, FinishReason, Request, Scheduler, SchedulerCfg};
use skipless::kvcache::CacheOpts;
use skipless::metrics::Metrics;
use skipless::model::{quantize, ModelWeights};
use skipless::sampler::grammar::Constraint;
use skipless::sampler::SamplerCfg;
use skipless::util::json::Json;
use skipless::util::rng::Xoshiro256;
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn maybe_quantize(w: ModelWeights) -> ModelWeights {
    match std::env::var("SKIPLESS_QUANTIZE").as_deref() {
        Ok("int8") => quantize(&w),
        _ => w,
    }
}

/// Random request mix: greedy, temperature-sampled, nucleus-sampled,
/// EOS-cut, and JSON-constrained (all of them speculation-eligible; the
/// acceptance rule dispatches on `is_greedy()` per request). Every request
/// gets its own random sampling seed, so stochastic streams are
/// independent and replay-deterministic. `stochastic_only` draws only
/// `temperature > 0` requests — used to prove speculation engages on the
/// stochastic path specifically. `long_prompts` stretches prompts across
/// several KV blocks so tight token budgets force genuinely multi-chunk
/// prefills. Sizes are bounded so even the tight pool can always hold one
/// request to completion — truncation is a *documented* divergence from
/// the oracle and belongs to other tests.
fn requests(
    rng: &mut Xoshiro256,
    n: usize,
    vocab: u64,
    long_prompts: bool,
    stochastic_only: bool,
) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let plen = if long_prompts {
                8 + rng.next_below(14) as usize
            } else {
                2 + rng.next_below(6) as usize
            };
            let prompt = (0..plen).map(|_| rng.next_below(vocab) as u32).collect();
            let max_new = 2 + rng.next_below(7) as usize;
            let mut req = Request::greedy(i as u64, prompt, max_new);
            req.seed = rng.next_u64();
            match rng.next_below(if stochastic_only { 2 } else { 6 }) {
                0 => {
                    req.sampler = SamplerCfg {
                        temperature: 0.8,
                        ..Default::default()
                    }
                }
                1 => {
                    req.sampler = SamplerCfg {
                        temperature: 0.7,
                        top_k: 40,
                        top_p: 0.95,
                    }
                }
                2 => req.eos = Some(rng.next_below(vocab) as u32),
                3 => {
                    // grammar-constrained; admission needs max_new >= 2
                    // and the sizes must still fit the tight pools
                    req.constrain = Some(Constraint::Json);
                    req.max_new_tokens = 4 + rng.next_below(10) as usize;
                    if rng.next_below(2) == 0 {
                        req.sampler = SamplerCfg {
                            temperature: 0.9,
                            ..Default::default()
                        };
                    }
                }
                _ => {}
            }
            req
        })
        .collect()
}

/// Oracle: each request alone on a roomy, non-speculative scheduler.
fn oracle(w: &ModelWeights, reqs: &[Request]) -> Vec<Vec<u32>> {
    reqs.iter()
        .map(|r| {
            let mut s = Scheduler::new(
                CpuEngine::new(w.clone(), 4, 8 << 20),
                SchedulerCfg::default(),
                Arc::new(Metrics::new()),
            );
            s.submit(r.clone());
            let done = s.run_to_completion();
            assert_eq!(done.len(), 1);
            done.into_iter().next().unwrap().tokens
        })
        .collect()
}

struct FuzzCase {
    seed: u64,
    spec_k: usize,
    /// Pool size in blocks (None = roomy).
    budget_blocks: Option<usize>,
    /// Stretch prompts over several blocks (multi-chunk prefills).
    long_prompts: bool,
    /// Randomly cancel requests mid-flight.
    cancels: bool,
    /// Draw only `temperature > 0` requests (see [`requests`]).
    stochastic_only: bool,
}

/// One fuzzed run: a random submit/step/cancel interleaving against a
/// scheduler with a random tight token budget and chunk size. Returns the
/// total speculative verify rounds observed.
fn fuzz_one(case: FuzzCase) -> u64 {
    let FuzzCase { seed, spec_k, budget_blocks, long_prompts, cancels, stochastic_only } = case;
    let cfg = ModelConfig::tiny_mha();
    let w = maybe_quantize(ModelWeights::init_vanilla(&cfg, 500 + seed));
    let mut rng = Xoshiro256::seed_from_u64(seed * 7919 + 13);
    let reqs = requests(&mut rng, 8, cfg.vocab_size as u64, long_prompts, stochastic_only);
    let want = oracle(&w, &reqs);

    let bytes_per_block = 2 * cfg.e() * cfg.n_layers * 4 * 4;
    let budget = budget_blocks.map(|b| b * bytes_per_block).unwrap_or(8 << 20);
    let metrics = Arc::new(Metrics::new());
    let sched_cfg = SchedulerCfg {
        max_running: 1 + rng.next_below(6) as usize,
        // tight: often smaller than one prompt, so prefills chunk across
        // steps and interleave with decodes, preemption, and swaps
        token_budget_per_step: 2 + rng.next_below(14) as usize,
        chunk_tokens: 1 + rng.next_below(6) as usize,
        spec_k,
    };
    let engine = CpuEngine::new(w.clone(), 4, budget);
    let mut s = if spec_k > 0 {
        let draft = CpuEngine::with_cache_opts(
            quantize(&w),
            4,
            budget,
            CacheOpts {
                quantized: true,
                ..Default::default()
            },
        );
        Scheduler::with_draft(engine, Box::new(draft), sched_cfg, Arc::clone(&metrics))
    } else {
        Scheduler::new(engine, sched_cfg, Arc::clone(&metrics))
    };

    let mut pending: VecDeque<Request> = reqs.iter().cloned().collect();
    let mut cancelled: HashSet<u64> = HashSet::new();
    let mut guard = 0u32;
    while !pending.is_empty() || !s.is_idle() {
        guard += 1;
        assert!(guard < 100_000, "seed {seed}: fuzz run wedged");
        if cancels && rng.next_below(11) == 0 {
            // cancel a random request wherever it currently lives; a false
            // return means it already finished (or was never submitted)
            let id = rng.next_below(reqs.len() as u64);
            if s.cancel(id) {
                cancelled.insert(id);
            }
        }
        if !pending.is_empty() && (s.is_idle() || rng.next_below(3) == 0) {
            s.submit(pending.pop_front().unwrap());
        } else {
            s.step();
        }
    }
    let mut done = s.take_done();
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), reqs.len(), "seed {seed}: request dropped");
    for (r, want) in done.iter().zip(&want) {
        assert_ne!(
            r.finish,
            FinishReason::Rejected,
            "seed {seed}: request {} spuriously rejected",
            r.id
        );
        if cancelled.contains(&r.id) {
            // sampling is seeded and replay-deterministic, so even a
            // request cancelled mid-prefill or mid-decode may only ever
            // have produced a prefix of its oracle stream
            assert_eq!(r.finish, FinishReason::Cancelled, "seed {seed}: request {}", r.id);
            assert!(
                r.tokens.len() <= want.len() && r.tokens[..] == want[..r.tokens.len()],
                "seed {seed}: cancelled request {} diverged from its oracle prefix",
                r.id
            );
        } else {
            assert_eq!(
                &r.tokens, want,
                "seed {seed}: request {} diverged from the sequential oracle",
                r.id
            );
            if reqs[r.id as usize].constrain.is_some() {
                assert_eq!(
                    r.finish,
                    FinishReason::Eos,
                    "seed {seed}: constrained request {} must finish via grammar \
                     completion",
                    r.id
                );
                let bytes: Vec<u8> = r
                    .tokens
                    .iter()
                    .map(|&t| u8::try_from(t).expect("constrained tokens are byte-vocab"))
                    .collect();
                let text = String::from_utf8_lossy(&bytes).into_owned();
                Json::parse(&text).unwrap_or_else(|e| {
                    panic!(
                        "seed {seed}: constrained request {} produced unparseable \
                         {text:?}: {e}",
                        r.id
                    )
                });
            }
        }
    }
    metrics.spec_rounds.load(Ordering::Relaxed)
}

/// Tight pool (6 blocks of 4 positions: far less than 8 requests need),
/// plain decode: preemption/swap/resume must not change one token.
#[test]
fn fuzz_plain_tight_pool() {
    for seed in 0..4 {
        fuzz_one(FuzzCase {
            seed,
            spec_k: 0,
            budget_blocks: Some(6),
            long_prompts: false,
            cancels: false,
            stochastic_only: false,
        });
    }
}

/// Tight pool with speculation: verify rollback, spec fall-backs, and
/// preemption interleave; outputs stay oracle-identical.
#[test]
fn fuzz_speculative_tight_pool() {
    for seed in 0..4 {
        fuzz_one(FuzzCase {
            seed,
            spec_k: 3,
            budget_blocks: Some(6),
            long_prompts: false,
            cancels: false,
            stochastic_only: false,
        });
    }
}

/// Roomy pool with speculation: drafting actually runs (no permanent
/// fall-back) and outputs stay oracle-identical.
#[test]
fn fuzz_speculative_roomy_pool() {
    let mut rounds = 0;
    for seed in 4..8 {
        rounds += fuzz_one(FuzzCase {
            seed,
            spec_k: 3,
            budget_blocks: None,
            long_prompts: false,
            cancels: false,
            stochastic_only: false,
        });
    }
    assert!(rounds > 0, "speculation never engaged across the roomy runs");
}

/// Chunked-prefill stress: multi-block prompts under token budgets smaller
/// than one prompt and a pool smaller than the working set, so mid-prefill
/// preemption, swap/resume, and cancel all interleave with decodes — with
/// speculation both off and on. Byte-identical to the oracle, none
/// dropped.
#[test]
fn fuzz_chunked_mid_prefill_preempt_swap_cancel() {
    for seed in 8..12 {
        fuzz_one(FuzzCase {
            seed,
            spec_k: 0,
            budget_blocks: Some(10),
            long_prompts: true,
            cancels: true,
            stochastic_only: false,
        });
        fuzz_one(FuzzCase {
            seed: seed + 100,
            spec_k: 3,
            budget_blocks: Some(10),
            long_prompts: true,
            cancels: true,
            stochastic_only: false,
        });
    }
}

/// Chunked prefills must actually have happened in the stress runs (the
/// harness would silently lose coverage if budgets stopped chunking).
#[test]
fn fuzz_chunked_runs_really_chunk() {
    let cfg = ModelConfig::tiny_mha();
    let w = maybe_quantize(ModelWeights::init_vanilla(&cfg, 777));
    let mut rng = Xoshiro256::seed_from_u64(777);
    let reqs = requests(&mut rng, 6, cfg.vocab_size as u64, true, false);
    let want = oracle(&w, &reqs);
    let metrics = Arc::new(Metrics::new());
    let mut s = Scheduler::new(
        CpuEngine::new(w, 4, 8 << 20),
        SchedulerCfg {
            token_budget_per_step: 6,
            chunk_tokens: 3,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    for r in &reqs {
        s.submit(r.clone());
    }
    let mut done = s.run_to_completion();
    done.sort_by_key(|r| r.id);
    for (r, want) in done.iter().zip(&want) {
        assert_eq!(&r.tokens, want, "request {} diverged", r.id);
    }
    let chunks = metrics.prefill_chunks.load(Ordering::Relaxed);
    let longest = reqs.iter().map(|r| r.prompt.len()).max().unwrap() as u64;
    assert!(
        chunks >= longest / 3,
        "expected multi-chunk prefills, saw {chunks} chunks"
    );
}

/// Stochastic speculative decoding must be *stream*-identical to plain
/// stochastic decoding for fixed per-request seeds (the oracle comparison
/// in [`fuzz_one`] asserts exactly that) — and speculation must actually
/// engage, because a regression back to the old "skip stochastic
/// requests" gate would pass the identity check trivially.
#[test]
fn fuzz_stochastic_spec_identical_and_engaged() {
    let mut rounds = 0;
    for seed in 16..20 {
        rounds += fuzz_one(FuzzCase {
            seed,
            spec_k: 3,
            budget_blocks: None,
            long_prompts: false,
            cancels: false,
            stochastic_only: true,
        });
    }
    assert!(rounds > 0, "speculation never engaged on the stochastic-only runs");
}
