//! Kernel-equivalence property suite: SIMD vs scalar-oracle BYTE-equality.
//!
//! Every rewritten kernel (`matmul_into`, `matmul_transb`, `matvec`,
//! `qmatmul`, and the fused paged-attention kernel) must produce output
//! byte-equal — not tolerance-close — to its restructured scalar oracle
//! (`matmul_ref` / `matmul_transb_ref` / `matvec_ref` / `qmatmul_ref` /
//! `attend_gathered`), under BOTH forced-scalar dispatch
//! (`SimdLevel::Scalar`) and whatever `simd::level()` auto-detects.
//!
//! The dimension sweep deliberately straddles the virtual lane width
//! (LANES = 8) and every cache-tile boundary (MC = 64, NC = 128,
//! KC = 256, KC_Q = 2048): {1, 3, lane−1, lane, lane+1, tile−1, tile,
//! tile+1, odd primes}. Integer i8×i8→i32 paths are exact in any
//! association, so they must match in full; f32 paths match because the
//! lane-strided accumulation order is fixed by contract.
//!
//! Also here: the qGEMM edge-case battery (i8 −128 saturation, all-zero
//! rows, per-row scale under/overflow, activation-quant roundtrip
//! determinism) and a seeded fuzz generator in the `sched_fuzz.rs` style.

use skipless::config::ModelConfig;
use skipless::kvcache::{BlockView, CacheOpts, KvCache, SeqId};
use skipless::linalg::gemm::{
    matmul, matmul_into, matmul_into_with, matmul_ref, matmul_transb, matmul_transb_into,
    matmul_transb_ref, matmul_transb_with, matvec, matvec_into, matvec_ref, matvec_with,
};
use skipless::linalg::qgemm::{qmatmul, qmatmul_into, qmatmul_ref, qmatmul_with, QuantScratch};
use skipless::linalg::simd::{self, SimdLevel, LANES};
use skipless::model::attention::HeadLayout;
use skipless::model::paged_attn::{attend_gathered, attend_paged, KvSegment};
use skipless::tensor::{Mat, QMat};
use skipless::util::rng::Xoshiro256;

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Both dispatch levels under test: the scalar reference arm and whatever
/// the host auto-detects (identical when SKIPLESS_SIMD=off — that run of
/// the suite is still meaningful because it pins oracle == kernel).
fn levels() -> Vec<SimdLevel> {
    let mut ls = vec![SimdLevel::Scalar];
    if simd::level() != SimdLevel::Scalar {
        ls.push(simd::level());
    }
    ls
}

/// M/N/K values straddling the lane width plus small odd primes.
const SMALL: &[usize] = &[1, 3, LANES - 1, LANES, LANES + 1, 13];

/// Targeted (m, n, k) shapes straddling the MC=64 / NC=128 / KC=256 tiles
/// (one below, on, and above each boundary, combined so a single shape
/// crosses all three at once) plus odd-prime spoilers.
const TILED: &[(usize, usize, usize)] = &[
    (63, 127, 255),
    (64, 128, 256),
    (65, 129, 257),
    (67, 131, 263), // odd primes past every tile edge
    (1, 768, 768),  // skinny batch-1 shape, above 1e6 flops: threaded column path
    (130, 7, 300),  // deep M, skinny N: serial row-blocked path + tail rows
];

// ---------------------------------------------------------------------------
// f32 GEMM family
// ---------------------------------------------------------------------------

fn check_f32_shape(m: usize, n: usize, k: usize, rng: &mut Xoshiro256) {
    let a = Mat::randn(m, k, 0.7, rng);
    let b = Mat::randn(k, n, 0.7, rng);
    let bt = b.transpose();
    let x: Vec<f32> = a.row(0).to_vec(); // matvec operand, len k

    let want_mm = matmul_ref(&a, &b);
    let want_tb = matmul_transb_ref(&a, &bt);
    let want_mv = matvec_ref(&a, &x);

    for lvl in levels() {
        let tag = format!("m={m} n={n} k={k} lvl={lvl:?}");
        let mut got = Mat::zeros(m, n);
        matmul_into_with(lvl, &a, &b, &mut got);
        assert_eq!(bits(got.as_slice()), bits(want_mm.as_slice()), "matmul {tag}");

        let got_tb = matmul_transb_with(lvl, &a, &bt);
        assert_eq!(bits(got_tb.as_slice()), bits(want_tb.as_slice()), "transb {tag}");

        let got_mv = matvec_with(lvl, &a, &x);
        assert_eq!(bits(&got_mv), bits(&want_mv), "matvec {tag}");
    }
}

/// The headline f32 sweep: full SMALL×SMALL×SMALL cross, then the
/// tile-straddling targeted shapes (which also push past the 1e6-flop
/// threading threshold, covering the parallel row/column drivers).
#[test]
fn f32_kernels_byte_equal_scalar_oracle_across_dim_sweep() {
    let mut rng = Xoshiro256::seed_from_u64(0x4e11);
    for &m in SMALL {
        for &n in SMALL {
            for &k in SMALL {
                check_f32_shape(m, n, k, &mut rng);
            }
        }
    }
    for &(m, n, k) in TILED {
        check_f32_shape(m, n, k, &mut rng);
    }
}

// ---------------------------------------------------------------------------
// i8 qGEMM
// ---------------------------------------------------------------------------

fn check_q_shape(m: usize, n: usize, k: usize, rng: &mut Xoshiro256) {
    let x = Mat::randn(m, k, 0.9, rng);
    let wf = Mat::randn(n, k, 0.05, rng);
    let w = QMat::quantize_rows(&wf);
    let want = qmatmul_ref(&x, &w);
    for lvl in levels() {
        let got = qmatmul_with(lvl, &x, &w);
        assert_eq!(
            bits(got.as_slice()),
            bits(want.as_slice()),
            "qmatmul m={m} n={n} k={k} lvl={lvl:?}"
        );
    }
}

/// qGEMM sweep: lane-straddling smalls plus k straddling the KC_Q = 2048
/// slab boundary (the i8 dot is exact in any association, so slabbed and
/// sequential accumulation must agree to the bit, not approximately).
#[test]
fn qgemm_byte_equal_sequential_oracle_across_dim_sweep() {
    let mut rng = Xoshiro256::seed_from_u64(0x9e44);
    for &m in SMALL {
        for &n in SMALL {
            for &k in SMALL {
                check_q_shape(m, n, k, &mut rng);
            }
        }
    }
    for (m, n, k) in [(5, 16, 2047), (5, 16, 2048), (5, 16, 2049), (4, 640, 640), (3, 17, 259)] {
        check_q_shape(m, n, k, &mut rng);
    }
}

/// i8 extremes: `QMat::from_raw` can carry −128 codes (activation quant
/// never emits them, but raw checkpoint loads can). −128 × −128 = 16384
/// must survive the widening pipelines (AVX2 madd pairs two such products
/// in i16→i32; NEON vmull_s8 widens first) without saturating.
#[test]
fn qgemm_minus_128_codes_exact() {
    let mut rng = Xoshiro256::seed_from_u64(0x8e);
    let (n, k) = (9, 67);
    // weight rows saturated at the extremes, mixed with random codes
    let mut data = vec![0i8; n * k];
    for (i, d) in data.iter_mut().enumerate() {
        *d = match i % 4 {
            0 => -128,
            1 => 127,
            2 => (rng.next_below(256) as i64 - 128) as i8,
            _ => -128,
        };
    }
    let w = QMat::from_raw(n, k, data, vec![0.013; n]);
    // activation rows near the quant clip point so x codes hit ±127
    let mut x = Mat::randn(5, k, 1.0, &mut rng);
    for v in x.as_mut_slice().iter_mut() {
        *v = v.signum() * 3.0 + *v;
    }
    let want = qmatmul_ref(&x, &w);
    for lvl in levels() {
        let got = qmatmul_with(lvl, &x, &w);
        assert_eq!(bits(got.as_slice()), bits(want.as_slice()), "lvl={lvl:?}");
    }
}

/// All-zero activation rows quantize to scale 0.0 + zero codes and must
/// produce exactly-zero output rows; all-zero weight rows (scale 0.0 via
/// from_raw) must produce exactly-zero output columns. Both under every
/// dispatch level.
#[test]
fn qgemm_all_zero_rows_exact_zeros() {
    let mut rng = Xoshiro256::seed_from_u64(0xa0);
    let (m, n, k) = (6, 10, 33);
    let mut x = Mat::randn(m, k, 0.8, &mut rng);
    x.row_mut(2).fill(0.0);
    x.row_mut(5).fill(0.0);
    let wf = Mat::randn(n, k, 0.05, &mut rng);
    let mut w = QMat::quantize_rows(&wf);
    // zero out weight row 3 the raw way: rebuild with a zeroed row + scale
    let mut codes = w.data().to_vec();
    let mut scales = w.scales().to_vec();
    codes[3 * k..4 * k].fill(0);
    scales[3] = 0.0;
    w = QMat::from_raw(n, k, codes, scales);

    let want = qmatmul_ref(&x, &w);
    for lvl in levels() {
        let got = qmatmul_with(lvl, &x, &w);
        assert_eq!(bits(got.as_slice()), bits(want.as_slice()), "lvl={lvl:?}");
        for r in [2usize, 5] {
            assert!(got.row(r).iter().all(|v| v.to_bits() == 0), "x row {r} not +0.0");
        }
        for r in 0..m {
            assert_eq!(got.at(r, 3).to_bits(), 0, "w col 3 not +0.0 at row {r}");
        }
    }
}

/// Per-row scale under/overflow: scales at 1e38 push the f32 epilogue to
/// ±inf, scales at 1e-40 land subnormal. The contract is bit-equality with
/// the oracle even there — the epilogue expression
/// `acc as f32 * x_scale * w_scale` is evaluated identically (left-assoc,
/// no FMA) on every path, so infs and subnormals must agree bitwise.
#[test]
fn qgemm_scale_overflow_underflow_bit_equal() {
    let mut rng = Xoshiro256::seed_from_u64(0xf1);
    let (n, k) = (8, 40);
    let mut data = vec![0i8; n * k];
    for d in data.iter_mut() {
        *d = (rng.next_below(255) as i64 - 127) as i8;
    }
    let mut scales = vec![0.01f32; n];
    scales[0] = 1e38; // overflow: epilogue product saturates to ±inf
    scales[1] = 1e-40; // underflow: subnormal weight scale
    scales[2] = f32::MIN_POSITIVE;
    let w = QMat::from_raw(n, k, data, scales);
    let x = Mat::randn(3, k, 2.0, &mut rng);
    let want = qmatmul_ref(&x, &w);
    assert!(
        want.row(0).iter().any(|v| v.is_infinite()),
        "overflow row failed to produce inf — test shape lost its teeth"
    );
    for lvl in levels() {
        let got = qmatmul_with(lvl, &x, &w);
        assert_eq!(bits(got.as_slice()), bits(want.as_slice()), "lvl={lvl:?}");
    }
}

/// Activation quantization must be a pure function of the row bytes:
/// quantizing the same matrix twice yields identical codes and scales, and
/// both match an inline sequential-fold reference (the vectorized absmax
/// uses exact ops — abs and max — so lane-striding cannot change it).
#[test]
fn activation_quant_roundtrip_deterministic() {
    let mut rng = Xoshiro256::seed_from_u64(0xde7);
    for k in [1usize, 7, 8, 9, 130, 641] {
        let x = Mat::randn(4, k, 1.3, &mut rng);
        let q1 = QMat::quantize_rows(&x);
        let q2 = QMat::quantize_rows(&x);
        assert_eq!(q1.data(), q2.data(), "codes differ across runs, k={k}");
        assert_eq!(bits(q1.scales()), bits(q2.scales()), "scales differ, k={k}");
        // inline scalar reference: sequential fold, same round/clamp expr
        for r in 0..x.rows() {
            let row = x.row(r);
            let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let scale = amax / 127.0;
            assert_eq!(q1.scale(r).to_bits(), scale.to_bits(), "scale r={r} k={k}");
            let inv = if scale == 0.0 { 0.0 } else { 1.0 / scale };
            for (c, &v) in row.iter().enumerate() {
                let code = (v * inv).round().clamp(-127.0, 127.0) as i8;
                assert_eq!(q1.row(r)[c], code, "code r={r} c={c} k={k}");
            }
        }
    }
}

/// Per-row quantization makes qmatmul batch-invariant: row r of a batched
/// call must be byte-equal to a single-row call on that row alone.
#[test]
fn qgemm_batch_invariant() {
    let mut rng = Xoshiro256::seed_from_u64(0xb4);
    let (m, n, k) = (7, 12, 129);
    let x = Mat::randn(m, k, 0.9, &mut rng);
    let w = QMat::quantize_rows(&Mat::randn(n, k, 0.04, &mut rng));
    for lvl in levels() {
        let batched = qmatmul_with(lvl, &x, &w);
        for r in 0..m {
            let one = Mat::from_vec(1, k, x.row(r).to_vec());
            let solo = qmatmul_with(lvl, &one, &w);
            assert_eq!(bits(batched.row(r)), bits(solo.row(0)), "row {r} lvl={lvl:?}");
        }
    }
}

/// Seeded fuzz in the `sched_fuzz.rs` style: random shapes and contents,
/// qmatmul and the f32 kernels checked byte-equal against their oracles.
/// Failures print the seed; rerun with it to reproduce.
#[test]
fn fuzz_random_shapes_byte_equal() {
    let base: u64 = std::env::var("SKIPLESS_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    for i in 0..24u64 {
        let seed = base + i;
        let mut rng = Xoshiro256::seed_from_u64(seed * 7919 + 13);
        let m = 1 + rng.next_below(33) as usize;
        let n = 1 + rng.next_below(65) as usize;
        let k = 1 + rng.next_below(300) as usize;
        eprintln!("fuzz seed={seed} m={m} n={n} k={k}");
        check_f32_shape(m, n, k, &mut rng);
        check_q_shape(m, n, k, &mut rng);
    }
}

// ---------------------------------------------------------------------------
// `_into` twins: the arena-facing kernels vs their allocating forms
// ---------------------------------------------------------------------------

/// Every `_into` kernel must be byte-equal to its allocating twin across
/// the full dimension sweep — with ONE persistent output/scratch set reused
/// for the whole sweep. The buffers start poisoned with NaN and then carry
/// whatever the previous (differently-shaped) iteration left behind, so any
/// read-before-write, stale-shape, or accumulate-into-garbage bug in the
/// reuse path changes bits and fails. This is exactly the step arena's
/// aliasing-adjacent reuse pattern (`util::arena`).
#[test]
fn into_variants_byte_equal_allocating_twins_on_dirty_scratch() {
    let mut rng = Xoshiro256::seed_from_u64(0x17e0);
    let mut o_mm = Mat::zeros(2, 2);
    let mut o_tb = Mat::zeros(2, 2);
    let mut o_q = Mat::zeros(2, 2);
    let mut o_mv: Vec<f32> = vec![f32::NAN; 7];
    let mut qs = QuantScratch::new();
    for o in [&mut o_mm, &mut o_tb, &mut o_q] {
        o.as_mut_slice().fill(f32::NAN);
    }

    let mut shapes: Vec<(usize, usize, usize)> = Vec::new();
    for &m in SMALL {
        for &n in SMALL {
            for &k in SMALL {
                shapes.push((m, n, k));
            }
        }
    }
    shapes.extend_from_slice(TILED);

    for (m, n, k) in shapes {
        let tag = format!("m={m} n={n} k={k}");
        let a = Mat::randn(m, k, 0.7, &mut rng);
        let b = Mat::randn(k, n, 0.7, &mut rng);
        let bt = b.transpose();
        let x: Vec<f32> = a.row(0).to_vec();
        let w = QMat::quantize_rows(&Mat::randn(n, k, 0.05, &mut rng));

        matmul_into(&a, &b, &mut o_mm);
        assert_eq!(bits(o_mm.as_slice()), bits(matmul(&a, &b).as_slice()), "matmul_into {tag}");

        matmul_transb_into(&a, &bt, &mut o_tb);
        assert_eq!(
            bits(o_tb.as_slice()),
            bits(matmul_transb(&a, &bt).as_slice()),
            "matmul_transb_into {tag}"
        );

        matvec_into(&a, &x, &mut o_mv);
        assert_eq!(bits(&o_mv), bits(&matvec(&a, &x)), "matvec_into {tag}");

        qmatmul_into(&a, &w, &mut qs, &mut o_q);
        assert_eq!(bits(o_q.as_slice()), bits(qmatmul(&a, &w).as_slice()), "qmatmul_into {tag}");
    }
}

// ---------------------------------------------------------------------------
// Paged-attention fused kernel
// ---------------------------------------------------------------------------

fn layout_of(cfg: &ModelConfig) -> HeadLayout {
    HeadLayout {
        n_heads: cfg.n_heads,
        n_kv_heads: cfg.n_kv_heads,
        head_dim: cfg.head_dim(),
    }
}

fn fill_random(c: &mut KvCache, cfg: &ModelConfig, id: SeqId, n: usize, rng: &mut Xoshiro256) {
    let e = cfg.e();
    for _ in 0..n {
        for layer in 0..cfg.n_layers {
            let k = Mat::randn(1, e, 0.8, rng);
            let v = Mat::randn(1, e, 0.8, rng);
            c.append(id, layer, k.row(0), v.row(0)).unwrap();
        }
        c.advance(id).unwrap();
    }
}

/// The fused kernel (vectorized QK^T scores, softmax reductions, weighted-V
/// accumulation, in-register u8 dequant) vs the scalar oracle
/// `attend_gathered`, over {f32, u8} × {MHA, GQA, MQA} views with history
/// lengths straddling the lane width and the block boundary. bt = 8 makes
/// block edges coincide with lane edges — the nastiest alignment.
#[test]
fn paged_attention_byte_equal_oracle_across_layouts_and_lengths() {
    for name in ["tiny-mha", "tiny-gqa", "tiny-mqa"] {
        for quantized in [false, true] {
            let cfg = ModelConfig::preset(name).unwrap();
            let layout = layout_of(&cfg);
            let e = cfg.e();
            let mut c = KvCache::with_opts(
                &cfg,
                8,
                512 * 1024,
                CacheOpts { quantized, ..Default::default() },
            );
            let mut rng = Xoshiro256::seed_from_u64(0x5eed);
            for t in [1usize, 3, LANES - 1, LANES, LANES + 1, 15, 16, 17] {
                let id = c.alloc_seq(t).unwrap();
                fill_random(&mut c, &cfg, id, t, &mut rng);
                let tail = Mat::randn(2, e, 0.5, &mut rng);
                for (ti, tails) in [
                    [KvSegment::empty(), KvSegment::empty()],
                    [KvSegment::rows(tail.row(0), tail.row(1), e), KvSegment::empty()],
                ]
                .into_iter()
                .enumerate()
                {
                    let q = Mat::randn(1, layout.d(), 0.5, &mut rng);
                    let n_tail: usize = tails.iter().map(|s| s.n).sum();
                    let (mut kg, mut vg) = (Vec::new(), Vec::new());
                    let t_cache = c.gather(id, 0, &mut kg, &mut vg).unwrap();
                    for seg in &tails {
                        kg.extend_from_slice(seg.k);
                        vg.extend_from_slice(seg.v);
                    }
                    let tt = t_cache + n_tail;
                    let mut want = vec![0.0f32; layout.d()];
                    attend_gathered(layout, q.row(0), &kg, &vg, tt, &mut want);
                    let views: Vec<BlockView> = c.seq_block_views(id, 0).unwrap().collect();
                    let mut got = vec![0.0f32; layout.d()];
                    let mut scores = Vec::new();
                    attend_paged(layout, q.row(0), &views, &tails, tt, &mut scores, &mut got);
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "{name} kv8={quantized} t={t} tails={ti}: fused != oracle"
                    );
                }
                c.free_seq(id).unwrap();
            }
        }
    }
}
