//! END-TO-END DRIVER (DESIGN.md §Experiment-index): serve a ~100M-parameter
//! skipless GQA transformer through the full stack — BPE tokenizer →
//! request queue → continuous-batching coordinator → batched engine →
//! paged KV cache → sampler — once with vanilla weights and once with the
//! paper's Q/P-merged weights, on identical request streams.
//!
//! Reports per-variant throughput (tokens/s), TTFT and per-token latency,
//! verifies the merged engine emits *identical text*, and prints the
//! measured vanilla/merged speedup next to the paper's bandwidth-model
//! prediction for this model. Optionally also boots the PJRT engine from
//! `artifacts/e2e-100m/` to prove the AOT path composes (pass --pjrt).
//!
//! Run: `cargo run --release --example serving_e2e [-- --pjrt]`
//! Results are recorded in EXPERIMENTS.md §E2E.

use skipless::bandwidth::{predicted_speedup, Hardware, F32_BYTES};
use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{Coordinator, CpuEngine, Request, SchedulerCfg};
use skipless::model::ModelWeights;
use skipless::runtime::PjrtEngine;
use skipless::surgery::{transform, Options};
use skipless::tokenizer::Bpe;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::time::Instant;

const CORPUS: &str = "the quick brown fox jumps over the lazy dog. \
    a transformer block without skip connections composes attention and \
    feed forward maps directly. removing the query and projection weights \
    keeps the function identical while streaming fewer bytes per token. \
    the key and value projections are all you need for grouped query \
    attention. memory bandwidth bounds batch one decoding on every \
    accelerator we measured. the quick brown fox returns.";

struct RunReport {
    label: String,
    tokens_out: Vec<Vec<u32>>,
    wall: std::time::Duration,
    decoded: u64,
    ttft_p50_us: f64,
    tpot_p50_us: f64,
}

fn drive(coordinator: &Coordinator, label: &str, prompts: &[Vec<u32>], max_new: usize) -> RunReport {
    // warm-up (compile caches, page in weights) — excluded from timing
    let _ = coordinator.generate(Request::greedy(u64::MAX, prompts[0].clone(), 2));
    let t0 = Instant::now();
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| coordinator.submit(Request::greedy(i as u64, p.clone(), max_new)))
        .collect();
    let mut tokens_out: Vec<Vec<u32>> = vec![Vec::new(); prompts.len()];
    for rx in rxs {
        let resp = rx.recv().expect("coordinator alive");
        if (resp.id as usize) < tokens_out.len() {
            tokens_out[resp.id as usize] = resp.tokens;
        }
    }
    let wall = t0.elapsed();
    let m = coordinator.metrics();
    RunReport {
        label: label.to_string(),
        tokens_out,
        wall,
        decoded: m.tokens_decoded.load(Ordering::Relaxed),
        ttft_p50_us: m.ttft.quantile(0.5).as_micros() as f64,
        tpot_p50_us: m.tpot.quantile(0.5).as_micros() as f64,
    }
}

fn print_report(r: &RunReport, total_tokens: usize) {
    println!(
        "  {:<16} wall {:>8.2?}  throughput {:>8.1} tok/s  ttft p50 {:>8.1}ms  tpot p50 {:>7.2}ms",
        r.label,
        r.wall,
        total_tokens as f64 / r.wall.as_secs_f64(),
        r.ttft_p50_us / 1e3,
        r.tpot_p50_us / 1e3,
    );
}

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let cfg = ModelConfig::e2e_100m();
    println!("== serving_e2e: {} ==", cfg.name);

    // --- tokenizer: train a byte-BPE on the corpus up to the model vocab
    let bpe = Bpe::train(CORPUS, (cfg.vocab_size).min(4096));
    println!(
        "tokenizer: byte-BPE, {} merges, vocab {}",
        bpe.n_merges(),
        bpe.vocab_size()
    );

    // --- request stream: natural-language prompts, batch-style workload
    let raw_prompts = [
        "the quick brown fox",
        "a transformer block without",
        "removing the query and projection",
        "memory bandwidth bounds",
        "the key and value projections",
        "attention and feed forward",
        "streaming fewer bytes per",
        "grouped query attention",
    ];
    let max_new = 24;
    let prompts: Vec<Vec<u32>> = raw_prompts.iter().map(|p| bpe.encode(p)).collect();
    let total_tokens = prompts.len() * max_new;
    println!("workload: {} requests × {} new tokens", prompts.len(), max_new);

    // --- weights: vanilla + Table-1 merged (same function, fewer weights)
    println!("\ninitializing + surgery...");
    let vanilla = ModelWeights::init_vanilla(&cfg, 99);
    let merged = transform(&vanilla, Variant::MergedQP, Options { skip_audit: true, ..Default::default() }).unwrap();
    println!(
        "  vanilla {:.1} MiB → merged {:.1} MiB (−{:.1}%)",
        vanilla.stored_bytes() as f64 / (1 << 20) as f64,
        merged.stored_bytes() as f64 / (1 << 20) as f64,
        100.0 * (vanilla.stored_bytes() - merged.stored_bytes()) as f64
            / vanilla.stored_bytes() as f64
    );

    // --- serve with the CPU engine, both variants, identical streams
    println!("\n== CPU engine (batched decode, paged KV cache) ==");
    let c_v = Coordinator::spawn(
        CpuEngine::new(vanilla.clone(), 16, 512 << 20),
        SchedulerCfg::default(),
    );
    let rep_v = drive(&c_v, "cpu/vanilla", &prompts, max_new);
    c_v.shutdown();
    let c_m = Coordinator::spawn(
        CpuEngine::new(merged.clone(), 16, 512 << 20),
        SchedulerCfg::default(),
    );
    let rep_m = drive(&c_m, "cpu/merged_qp", &prompts, max_new);
    c_m.shutdown();
    print_report(&rep_v, total_tokens);
    print_report(&rep_m, total_tokens);

    // merged must generate the SAME text
    assert_eq!(rep_v.tokens_out, rep_m.tokens_out, "merged engine diverged!");
    println!("  merged output identical to vanilla ✓");
    println!("\n  sample completions:");
    for (p, toks) in raw_prompts.iter().zip(&rep_m.tokens_out).take(3) {
        let text = bpe.decode_lossy(toks);
        let clean: String = text.chars().take(48).collect();
        println!("    '{p}' → {:?}", clean);
    }

    let measured = rep_v.wall.as_secs_f64() / rep_m.wall.as_secs_f64();
    let predicted = predicted_speedup(&cfg, Variant::MergedQP, &Hardware::cpu_like(), prompts.len(), 24, F32_BYTES);
    let predicted_b1 = predicted_speedup(&cfg, Variant::MergedQP, &Hardware::cpu_like(), 1, 24, F32_BYTES);
    println!(
        "\n  measured wall-clock speedup (batch {}): {:.3}x   model-predicted: {:.3}x (batch-1 ideal: {:.3}x)",
        prompts.len(),
        measured,
        predicted,
        predicted_b1
    );
    println!("  (decoded counters: vanilla {} / merged {})", rep_v.decoded, rep_m.decoded);

    // --- optional: the AOT/PJRT path end to end on the same model
    if use_pjrt {
        let dir = Path::new("artifacts/e2e-100m");
        if dir.join("vanilla/manifest.json").exists() {
            println!("\n== PJRT engine (AOT jax+pallas artifacts) ==");
            for (label, w, sub) in [
                ("pjrt/vanilla", vanilla.clone(), "vanilla"),
                ("pjrt/merged_qp", merged.clone(), "merged_qp"),
            ] {
                let d = dir.join(sub);
                let c = Coordinator::spawn_with(
                    move || PjrtEngine::boot(&d, &w, 16).expect("pjrt boot"),
                    SchedulerCfg::default(),
                );
                // shorter stream: PJRT CPU round-trips caches per step
                let small: Vec<Vec<u32>> = prompts.iter().take(4).cloned().collect();
                let rep = drive(&c, label, &small, 8);
                print_report(&rep, small.len() * 8);
                c.shutdown();
            }
        } else {
            println!("\n(skipping PJRT: run `make artifacts` to build artifacts/e2e-100m)");
        }
    }
    println!("\nserving_e2e complete.");
}
