//! Quickstart: the paper's trick in ~60 lines of library calls.
//!
//! 1. Build a random skipless GQA model (Mistral-shaped, tiny).
//! 2. Run the paper's Table-1 surgery: remove Q and P.
//! 3. Verify the merged model computes the *same function*.
//! 4. Generate text through the serving coordinator with both.
//!
//! Run: `cargo run --release --example quickstart`

use skipless::config::{ModelConfig, Variant};
use skipless::coordinator::{Coordinator, CpuEngine, Request, SchedulerCfg};
use skipless::model::{prefill, ModelWeights};
use skipless::params::count_weights;
use skipless::surgery::{transform, Options};

fn main() {
    // 1. a skipless transformer with grouped-query attention (GQA) — the
    //    case where earlier work (He & Hofmann) could NOT remove weights.
    let cfg = ModelConfig::tiny_gqa();
    let vanilla = ModelWeights::init_vanilla(&cfg, 7);
    println!(
        "model: {} (GQA {}:{}, {} layers) — {} weights",
        cfg.name,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.n_layers,
        vanilla.stored_weights()
    );

    // 2. surgery: merge P into the FFN (M* = P·M) and fold Q into the
    //    upstream output matrices (O* = O·Q, K* = Q⁻¹K, V* = Q⁻¹V).
    let merged = transform(&vanilla, Variant::MergedQP, Options::default()).unwrap();
    let removed = vanilla.stored_weights() - merged.stored_weights();
    println!(
        "after Q/P removal: {} weights (−{} = −{:.1}%)",
        merged.stored_weights(),
        removed,
        100.0 * removed as f64 / vanilla.stored_weights() as f64
    );
    assert_eq!(merged.stored_weights(), count_weights(&cfg, Variant::MergedQP).total());

    // 3. mathematically identical: same logits to f32 roundoff.
    let prompt = [11u32, 42, 7, 3];
    let (l0, _) = prefill(&vanilla, &prompt);
    let (l1, _) = prefill(&merged, &prompt);
    println!("relative logits error after surgery: {:.3e}", l1.rel_fro_err(&l0));

    // 4. serve both through the coordinator — identical generations.
    let c_vanilla = Coordinator::spawn(CpuEngine::new(vanilla, 16, 64 << 20), SchedulerCfg::default());
    let c_merged = Coordinator::spawn(CpuEngine::new(merged, 16, 64 << 20), SchedulerCfg::default());
    let rv = c_vanilla.generate(Request::greedy(1, prompt.to_vec(), 12));
    let rm = c_merged.generate(Request::greedy(1, prompt.to_vec(), 12));
    println!("vanilla tokens: {:?}", rv.tokens);
    println!("merged  tokens: {:?}", rm.tokens);
    assert_eq!(rv.tokens, rm.tokens, "merged model diverged!");
    println!("OK: merged model generates identical text with {removed} fewer weights");
    c_vanilla.shutdown();
    c_merged.shutdown();
}
