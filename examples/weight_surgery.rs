//! Checkpoint surgery at realistic scale: a Mistral-7B-architecture model
//! shrunk to a CPU-friendly size (~100M params), run through every valid
//! transform with a full §4 invertibility audit, equivalence verification,
//! and byte-savings accounting — the workflow a practitioner would run on a
//! real checkpoint before deploying the merged weights.
//!
//! Run: `cargo run --release --example weight_surgery`

use skipless::config::{ModelConfig, Variant};
use skipless::model::{greedy_generate, prefill, weights_io, ModelWeights};
use skipless::surgery::{audit, audit_summary, transform, Options, SurgeryError};
use std::time::Instant;

fn main() {
    // Mistral-7B geometry scaled down (GQA 10:2, SwiGLU, serial) — same
    // ratios as the paper's table, ~100M parameters.
    let cfg = ModelConfig::e2e_100m();
    println!("== initializing {} ({} layers, GQA {}:{}) ==", cfg.name, cfg.n_layers, cfg.n_heads, cfg.n_kv_heads);
    let t0 = Instant::now();
    let vanilla = ModelWeights::init_vanilla(&cfg, 20240311);
    println!(
        "init: {} weights ({:.1} MiB) in {:?}",
        vanilla.stored_weights(),
        vanilla.stored_bytes() as f64 / (1 << 20) as f64,
        t0.elapsed()
    );

    // §4 audit first: every square attention matrix must be invertible.
    println!("\n== §4 invertibility audit (Q and P are square for GQA) ==");
    let t0 = Instant::now();
    let rows = audit(&vanilla);
    let (all_inv, worst) = audit_summary(&rows);
    println!(
        "{} matrices audited in {:?}: all invertible = {}, worst κ₁ ≈ {:.3e}",
        rows.len(),
        t0.elapsed(),
        all_inv,
        worst
    );

    // Q/P removal (valid for GQA).
    println!("\n== surgery: remove Q and P (paper Fig. 1b / Table 1) ==");
    let t0 = Instant::now();
    let merged = transform(&vanilla, Variant::MergedQP, Options { skip_audit: true, ..Default::default() }).unwrap();
    let dt = t0.elapsed();
    let saved = vanilla.stored_bytes() - merged.stored_bytes();
    println!(
        "surgery took {:?}; weights {} → {} (−{:.1}% = {:.1} MiB less to stream per token)",
        dt,
        vanilla.stored_weights(),
        merged.stored_weights(),
        100.0 * saved as f64 / vanilla.stored_bytes() as f64,
        saved as f64 / (1 << 20) as f64
    );

    // K/P removal must be refused for GQA — the paper's core observation.
    match transform(&vanilla, Variant::MergedKP, Options::default()) {
        Err(SurgeryError::Unsupported { .. }) => {
            println!("K/P removal correctly refused for GQA (needs e = d, i.e. MHA)")
        }
        other => panic!("expected Unsupported, got {:?}", other.map(|_| ())),
    }

    // Equivalence on logits...
    println!("\n== verification ==");
    let prompt: Vec<u32> = (0..24).map(|i| (i * 37 + 11) % cfg.vocab_size as u32).collect();
    let (l0, _) = prefill(&vanilla, &prompt);
    let (l1, _) = prefill(&merged, &prompt);
    println!("relative logits error: {:.3e}", l1.rel_fro_err(&l0));
    // ...and on generated text.
    let g0 = greedy_generate(&vanilla, &prompt[..8], 16);
    let g1 = greedy_generate(&merged, &prompt[..8], 16);
    assert_eq!(g0, g1, "generation diverged after surgery");
    println!("greedy generations identical: {:?}...", &g0[..8.min(g0.len())]);

    // Round-trip through the on-disk format.
    let dir = std::env::temp_dir();
    let path = dir.join("e2e_100m.merged_qp.swt");
    let t0 = Instant::now();
    weights_io::save(&merged, &path).unwrap();
    let loaded = weights_io::load(&path).unwrap();
    println!(
        "\nsaved+loaded {} ({:.1} MiB) in {:?}; bit-exact: {}",
        path.display(),
        merged.stored_bytes() as f64 / (1 << 20) as f64,
        t0.elapsed(),
        loaded.stored_weights() == merged.stored_weights()
    );
    let _ = std::fs::remove_file(&path);
}
