//! Regenerate every number the paper reports, in one run:
//!
//! * §3 table — configs, per-layer and total weight counts, savings %,
//!   batch-1 speedups for Pythia-6.9B and Mistral-7B (exact arithmetic).
//! * Fig. 1(b,c,d) & Fig. 2 — numerical equivalence of each merge.
//! * Fig. 3 — parallel-block variants (carry-merged exact form).
//! * §4 — invertibility audit at Mistral's true dimension (d=4096).
//! * §5/Fig. 4 pointer — where the ablation lives.
//!
//! Run: `cargo run --release --example paper_tables`

use skipless::bandwidth::{compute_bound_batch, predicted_speedup, Hardware};
use skipless::config::{ModelConfig, Variant};
use skipless::linalg::cond_estimate;
use skipless::model::{prefill, ModelWeights};
use skipless::params::{batch1_speedup, count_weights, savings_fraction, table3_report};
use skipless::surgery::{transform, Options};
use skipless::tensor::Mat;
use skipless::util::rng::Xoshiro256;

fn main() {
    // ---------------- §3 table ----------------
    println!("================= §3 table =================\n");
    for preset in ["pythia-6.9b", "mistral-7b"] {
        let cfg = ModelConfig::preset(preset).unwrap();
        print!("{}", table3_report(&cfg));
        println!();
    }
    println!("paper:   pythia 16% / 1.19x      mistral 15% / 1.17x");
    let py = ModelConfig::pythia_6_9b();
    let mi = ModelConfig::mistral_7b();
    println!(
        "ours :   pythia {:.0}% / {:.2}x      mistral {:.0}% / {:.2}x\n",
        100.0 * savings_fraction(&py, Variant::MergedQP),
        batch1_speedup(&py, Variant::MergedQP),
        100.0 * savings_fraction(&mi, Variant::MergedQP),
        batch1_speedup(&mi, Variant::MergedQP),
    );
    // exact cells
    let w = count_weights(&mi, Variant::Vanilla);
    assert_eq!(w.qp_per_layer(), 33_554_432);
    assert_eq!(w.kv_per_layer(), 8_388_608);
    assert_eq!(w.ffn_per_layer, 176_160_768);
    assert_eq!(w.embeddings, 262_144_000);

    // ---------------- Fig. 1 / Fig. 2 equivalence ----------------
    println!("========== Fig. 1/2: serial-merge equivalence ==========\n");
    let toks = [5u32, 17, 3, 42, 8, 1];
    println!("{:<14} {:<11} {:>14}", "config", "variant", "rel logits err");
    for (preset, variants) in [
        ("tiny-mha", vec![Variant::MergedQP, Variant::MergedKP, Variant::MergedVP]),
        ("tiny-gqa", vec![Variant::MergedQP]),
        ("tiny-mqa", vec![Variant::MergedQP]),
    ] {
        let cfg = ModelConfig::preset(preset).unwrap();
        let vanilla = ModelWeights::init_vanilla(&cfg, 1234);
        let (l0, _) = prefill(&vanilla, &toks);
        for v in variants {
            let merged = transform(&vanilla, v, Options::default()).unwrap();
            let (l1, _) = prefill(&merged, &toks);
            println!("{:<14} {:<11} {:>14.3e}", preset, v.name(), l1.rel_fro_err(&l0));
        }
    }
    println!("(K/P and V/P removal on GQA/MQA: rejected — requires e = d)\n");

    // ---------------- Fig. 3 parallel ----------------
    println!("========== Fig. 3: parallel-block merges (carry-merged) ==========\n");
    let cfg = ModelConfig::tiny_parallel();
    let vanilla = ModelWeights::init_vanilla(&cfg, 555);
    let (l0, _) = prefill(&vanilla, &toks);
    for v in [Variant::MergedQP, Variant::MergedKP, Variant::MergedVP] {
        let merged = transform(&vanilla, v, Options::default()).unwrap();
        let (l1, _) = prefill(&merged, &toks);
        let saved = vanilla.stored_weights() - merged.stored_weights();
        println!(
            "tiny-parallel  {:<11} rel err {:>10.3e}   −{} weights (d² per block; see DESIGN.md §Parallel)",
            v.name(),
            l1.rel_fro_err(&l0),
            saved
        );
    }
    println!();

    // ---------------- §4 invertibility at Mistral dims ----------------
    println!("========== §4: invertibility at d=4096 (Mistral dimension) ==========\n");
    let mut rng = Xoshiro256::seed_from_u64(20240311);
    let n_mats = 4;
    let mut worst = 0.0f64;
    for i in 0..n_mats {
        let m = Mat::randn(4096, 4096, 1.0 / 64.0, &mut rng);
        let k = cond_estimate(&m).expect("invertible");
        println!("  random 4096×4096 #{i}: invertible, κ₁ ≈ {k:.3e}");
        worst = worst.max(k);
    }
    println!(
        "\n  {n_mats}/{n_mats} invertible (substitute for Mistral-7B's checkpoints — \
         the paper itself notes random square matrices are a.s. invertible); worst κ₁ ≈ {worst:.3e}\n"
    );

    // ---------------- speedup crossover (bandwidth model) ----------------
    println!("========== batch sweep: where the 1.17x fades ==========\n");
    let hw = Hardware::a100_like();
    println!("  batch   ctx=512   ctx=4096   (mistral-7b, fp16, a100-like)");
    for b in [1usize, 4, 16, 64, 256, 1024] {
        println!(
            "  {:>5}   {:>7.3}   {:>8.3}",
            b,
            predicted_speedup(&mi, Variant::MergedQP, &hw, b, 512, 2.0),
            predicted_speedup(&mi, Variant::MergedQP, &hw, b, 4096, 2.0)
        );
    }
    println!(
        "\n  compute-bound crossover batch ≈ {}  (peak·bytes/2·BW)\n",
        compute_bound_batch(&mi, &hw, 2.0)
    );
    println!("Fig. 4 ablation: `cargo bench --bench fig4_ablation` and `make train-demo`.");
}
